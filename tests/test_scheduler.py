"""Workload Scheduler (§4.4) + simulator invariants, incl. hypothesis
property tests over random traces."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.cluster import (
    SimConfig,
    TraceConfig,
    clone_jobs,
    generate_trace,
    policies,
)
from repro.core.jobs import LLM_PROFILES, Job, exec_time, iter_time


def _trace(load="medium", S=1.0, seed=0, minutes=5):
    return generate_trace(TraceConfig(load=load, slo_emergence=S, seed=seed,
                                      minutes=minutes))


def test_all_jobs_complete_and_accounted():
    jobs = _trace()
    for name in ("prompttuner", "infless", "elasticflow"):
        res = policies.build(name, SimConfig(max_gpus=32)).run(clone_jobs(jobs))
        assert len(res.records) == len(jobs), name
        finished = [r for r in res.records if np.isfinite(r.finish)]
        assert len(finished) == len(jobs), f"{name}: unfinished jobs"
        assert res.cost > 0


def test_gpu_conservation_prompttuner():
    """warm pools + cold pool never exceed the fleet; nothing negative."""
    jobs = _trace(minutes=3)
    cfg = SimConfig(max_gpus=32)
    sys_ = policies.build("prompttuner", cfg)

    orig = sys_._schedule

    def checked():
        orig()
        total_warm = sum(p.total() for p in sys_.pools.values())
        assert sys_.cold_free >= 0
        assert total_warm + sys_.cold_free <= cfg.max_gpus
        for p in sys_.pools.values():
            assert p.busy >= 0 and len(p.idle) >= 0

    sys_._schedule = checked
    sys_.run(clone_jobs(jobs))


def test_iter_time_near_linear_scaling():
    prof = LLM_PROFILES["vicuna-7b"]
    t1 = iter_time(prof, 1)
    t8 = iter_time(prof, 8)
    assert t8 < t1 / 7.0                       # near-linear
    assert t8 > t1 / 8.0                       # but not superlinear


def test_exec_time_includes_bank_and_overhead():
    j = Job(0, "gpt2-base", 0.0, 100.0, iters_manual=100, iters_bank=25)
    prof = j.profile()
    no_bank = exec_time(j, 1, used_bank=False, alloc_overhead=2.0)
    bank = exec_time(j, 1, used_bank=True, alloc_overhead=2.0)
    assert no_bank == pytest.approx(100 * prof.iter_time_1replica + 2.0)
    assert bank == pytest.approx(
        25 * prof.iter_time_1replica + 2.0 + prof.bank_lookup_s)


def test_latency_budget_gates_bank():
    cfg = SimConfig(max_gpus=8)
    sys_ = policies.build("prompttuner", cfg)
    prof = LLM_PROFILES["gpt2-base"]
    slo_ok = prof.bank_lookup_s / cfg.latency_budget_frac + 1.0
    slo_bad = prof.bank_lookup_s / cfg.latency_budget_frac - 1.0
    j_ok = Job(0, "gpt2-base", 0.0, slo_ok, 100, 25)
    j_bad = Job(1, "gpt2-base", 0.0, slo_bad, 100, 25)
    assert sys_.use_bank_for(j_ok) is True
    assert sys_.use_bank_for(j_bad) is False


def test_bank_reduces_cost_and_violation():
    jobs = _trace(load="high", S=0.8, minutes=5)
    on = policies.build("prompttuner", SimConfig(max_gpus=24)).run(
        clone_jobs(jobs)).summary()
    off = policies.build("prompttuner",
                      SimConfig(max_gpus=24, use_bank=False)).run(
        clone_jobs(jobs)).summary()
    assert on["slo_violation_pct"] <= off["slo_violation_pct"]
    assert on["cost_usd"] < off["cost_usd"]


def test_delay_schedulable_reduces_cost():
    jobs = _trace(load="high", S=1.2, minutes=5)
    with_delay = policies.build("prompttuner", SimConfig(max_gpus=24)).run(
        clone_jobs(jobs)).summary()
    without = policies.build(
        "prompttuner", SimConfig(max_gpus=24, use_delay=False)).run(
        clone_jobs(jobs)).summary()
    assert with_delay["cost_usd"] <= without["cost_usd"] * 1.05


def test_warm_reuse_beats_cold_only():
    jobs = _trace(load="medium", S=0.6, minutes=5)
    warm = policies.build("prompttuner", SimConfig(max_gpus=24)).run(
        clone_jobs(jobs)).summary()
    no_warm = policies.build(
        "prompttuner", SimConfig(max_gpus=24, use_warm=False)).run(
        clone_jobs(jobs)).summary()
    assert warm["slo_violation_pct"] <= no_warm["slo_violation_pct"]


def test_elasticflow_bills_full_cluster():
    jobs = _trace(minutes=2)
    cfg = SimConfig(max_gpus=16)
    res = policies.build("elasticflow", cfg).run(clone_jobs(jobs))
    expected = cfg.max_gpus * res.makespan * cfg.price_per_gpu_s
    assert res.cost == pytest.approx(expected, rel=0.05)


def test_prompttuner_beats_baselines_end_to_end():
    """The paper's headline ordering on a medium trace."""
    jobs = _trace(load="medium", S=1.0, seed=1, minutes=10)
    out = {}
    for name in ("prompttuner", "infless", "elasticflow"):
        out[name] = policies.build(name, SimConfig(max_gpus=32)).run(
            clone_jobs(jobs)).summary()
    assert (out["prompttuner"]["slo_violation_pct"]
            <= out["infless"]["slo_violation_pct"])
    assert (out["prompttuner"]["cost_usd"] < out["elasticflow"]["cost_usd"])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       gpus=st.sampled_from([8, 16, 32]),
       S=st.floats(0.5, 2.0))
def test_sim_invariants_random_traces(seed, gpus, S):
    """Property: for any trace/fleet/SLO emergence — every job is recorded
    exactly once, finish >= start >= submit, cost >= 0, gpus allocated in
    replica units."""
    jobs = generate_trace(TraceConfig(load="low", slo_emergence=S,
                                      seed=seed, minutes=3))
    res = policies.build("prompttuner", SimConfig(max_gpus=gpus)).run(
        clone_jobs(jobs))
    assert len(res.records) == len(jobs)
    seen = set()
    for r in res.records:
        assert r.job.job_id not in seen
        seen.add(r.job.job_id)
        if np.isfinite(r.finish):
            assert r.finish >= r.start >= r.job.submit_time - 1e-6
            prof = r.job.profile()
            assert r.gpus % prof.gpus_per_replica == 0
    assert res.cost >= 0
