"""LPT algorithms: soft prompt + prefix (reparameterized) variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TuneConfig
from repro.data import LoaderConfig, TaskLoader
from repro.tuning import PromptTuner, activation_features


def test_soft_prompt_tuning_reduces_loss(pre_base):
    pre = pre_base
    task = pre.tasks[5]
    tc = TuneConfig(lr=0.5, batch_size=16, eval_every=5, max_iters=60)
    tuner = PromptTuner(pre.model, tc)
    loader = TaskLoader(task, LoaderConfig(batch_size=16))
    pp = tuner.init_prompt(pre.params, jax.random.key(0))
    eb = loader.eval_batch(16)
    before = tuner.score(pp, pre.params, eb)
    res = tuner.tune(pre.params, loader, pp, max_iters=60)
    after = tuner.score(res["prompt"], pre.params, eb)
    assert after < before


def test_prefix_variant_runs(pre_base):
    pre = pre_base
    tc = TuneConfig(algorithm="prefix", lr=0.3, batch_size=8,
                    eval_every=5, max_iters=10)
    tuner = PromptTuner(pre.model, tc)
    loader = TaskLoader(pre.tasks[0], LoaderConfig(batch_size=8))
    pp = tuner.init_prompt(pre.params, jax.random.key(1))
    assert "reparam_w" in pp and "reparam_v" in pp
    res = tuner.tune(pre.params, loader, pp, max_iters=10)
    assert res["iters"] == 10
    assert np.isfinite(res["history"][-1][2]) if res["history"] else True


def test_tune_returns_zero_ita_when_target_met(pre_base):
    """Prompt reusing's endgame: an init already at target has ITA=0."""
    pre = pre_base
    task = pre.tasks[3]
    tc = TuneConfig(lr=0.5, batch_size=16)
    tuner = PromptTuner(pre.model, tc)
    loader = TaskLoader(task, LoaderConfig(batch_size=16))
    own = {"soft_prompt": jnp.asarray(pre.task_prompts[task.task_id])}
    score = tuner.score(own, pre.params, loader.eval_batch(16))
    res = tuner.tune(pre.params, loader, own, target_loss=score + 1.0,
                     max_iters=50)
    assert res["iters"] == 0 and res["reached"]


def test_activation_features_discriminate_tasks(pre_base):
    """Features of prompts for the same family must be closer than
    across families (the property K-medoid clustering relies on)."""
    pre = pre_base
    fam = {}
    for tid in ["shift:0", "shift:1", "xor:0", "xor:1"]:
        fam[tid] = activation_features(
            pre.model, pre.params, jnp.asarray(pre.task_prompts[tid]))
    def cos(a, b):
        return float(np.dot(a, b)
                     / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-9))
    within = cos(fam["shift:0"], fam["shift:1"])
    across = cos(fam["shift:0"], fam["xor:0"])
    assert within > across


def test_init_prompt_from_tokens(pre_base):
    pre = pre_base
    tc = TuneConfig(prompt_len=4)
    tuner = PromptTuner(pre.model, tc)
    toks = jnp.array([3, 4, 5, 6])
    pp = tuner.init_prompt(pre.params, jax.random.key(0), token_ids=toks)
    expected = np.asarray(pre.params["embedding"])[np.asarray(toks)]
    np.testing.assert_allclose(np.asarray(pp["soft_prompt"]), expected,
                               rtol=1e-6)
