"""PromptTunerService front door: latency-budget routing, bank lookup,
scheduling, and online bank insertion (Fig 5b) end-to-end."""
import numpy as np
import pytest

from repro.api import JobHandle, JobResult, PromptTunerService, SubmitRequest
from repro.cluster import SimConfig
from repro.core.jobs import LLM_PROFILES
from repro.core.prompt_bank import PromptBank, PromptEntry, cosine_distance


def _mk_bank(n=60, d=8, k=6, seed=0, capacity=3000):
    """Synthetic bank: `k` gaussian feature blobs, one entry family per
    blob (mirrors tests/test_prompt_bank.py)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d))
    entries = []
    for i in range(n):
        c = i % k
        feat = centers[c] + 0.05 * rng.normal(size=d)
        entries.append(PromptEntry(
            prompt=rng.normal(size=(4, d)).astype(np.float32),
            feature=feat.astype(np.float32),
            origin=f"blob{c}/v{i // k}",
        ))
    bank = PromptBank(capacity=capacity, num_clusters=k, seed=seed)
    bank.add_candidates(entries)
    bank.build()
    return bank, centers


def _score_factory(req):
    """Eqn-1 stand-in: score = cosine distance of the entry's feature to
    the request's feature (lower is better)."""
    target = np.asarray(req.feature)

    def score(entry):
        return float(cosine_distance(entry.feature[None], target[None])[0, 0])

    return score


def _req(task_id, llm="gpt2-base", slo=300.0, feature=None, prompt=None,
         iters_manual=200, iters_bank=60, submit_time=None):
    return SubmitRequest(task_id=task_id, llm=llm, slo=slo,
                         iters_manual=iters_manual, iters_bank=iters_bank,
                         submit_time=submit_time, prompt=prompt,
                         feature=feature)


def test_latency_budget_routing():
    svc = PromptTunerService(SimConfig(max_gpus=8))
    prof = LLM_PROFILES["gpt2-base"]
    tight = prof.bank_lookup_s / svc.cfg.latency_budget_frac - 1.0
    loose = prof.bank_lookup_s / svc.cfg.latency_budget_frac + 1.0
    assert svc.submit(_req("a", slo=loose)).routed_through_bank is True
    assert svc.submit(_req("b", slo=tight)).routed_through_bank is False
    # Table 8 'w/o Latency Budget': bank for every request
    svc2 = PromptTunerService(SimConfig(max_gpus=8, use_latency_budget=False))
    assert svc2.submit(_req("c", slo=tight)).routed_through_bank is True
    svc3 = PromptTunerService(SimConfig(max_gpus=8, use_bank=False))
    assert svc3.submit(_req("d", slo=loose)).routed_through_bank is False


def test_submit_rejects_unknown_llm():
    svc = PromptTunerService(SimConfig(max_gpus=8))
    with pytest.raises(KeyError, match="unknown LLM"):
        svc.submit(_req("a", llm="gpt5"))


def test_end_to_end_bank_lookup_tune_insert():
    """The Fig 5b loop: lookup picks a near-feature entry, the scheduler
    runs the job, and the freshly tuned prompt lands back in the bank."""
    bank, centers = _mk_bank()
    size0 = len(bank)
    svc = PromptTunerService(SimConfig(max_gpus=16), bank=bank,
                             score_fn_factory=_score_factory)
    rng = np.random.default_rng(1)
    handles = []
    for i in range(6):
        blob = i % 3
        feat = (centers[blob] + 0.05 * rng.normal(size=8)).astype(np.float32)
        handles.append(svc.submit(_req(
            f"task{i}", slo=300.0 + 10 * i, feature=feat,
            prompt=rng.normal(size=(4, 8)).astype(np.float32),
            submit_time=float(i))))
    for h in handles:
        assert isinstance(h, JobHandle)
        assert h.routed_through_bank is True
        # the two-layer lookup found the entry family nearest in feature
        assert h.bank_origin is not None and h.bank_score is not None
    results = svc.run_until_idle()
    assert len(results) == 6
    for r in results:
        assert isinstance(r, JobResult)
        assert r.completed and r.finish >= r.start >= r.handle.submitted_at
        assert r.inserted_to_bank is True       # online insertion happened
    assert len(bank) == size0 + 6
    online = [e.origin for e in bank.entries if e.origin.endswith("/online")]
    assert len(online) == 6
    s = svc.summary()
    assert s["jobs"] == 6 and s["cost_usd"] > 0


def test_lookup_matches_request_feature_blob():
    """Lookup quality: a request near blob b's center should get a blob-b
    prompt back (the bank's two-layer search works through the facade)."""
    bank, centers = _mk_bank(seed=3)
    svc = PromptTunerService(SimConfig(max_gpus=8), bank=bank,
                             score_fn_factory=_score_factory)
    for blob in range(3):
        h = svc.submit(_req(f"t{blob}", slo=500.0,
                            feature=centers[blob].astype(np.float32)))
        assert h.bank_origin.startswith(f"blob{blob}/")


def test_incremental_submit_run_cycles():
    """The facade supports submit -> run -> submit -> run; the clock and
    records accumulate monotonically and nothing is double-reported."""
    svc = PromptTunerService(SimConfig(max_gpus=8))
    h1 = svc.submit(_req("a", slo=400.0))
    first = svc.run_until_idle()
    assert [r.handle.job_id for r in first] == [h1.job_id]
    t_after_first = svc.now
    h2 = svc.submit(_req("b", slo=400.0))        # submit_time defaults to now
    assert h2.submitted_at == t_after_first
    second = svc.run_until_idle()
    assert [r.handle.job_id for r in second] == [h2.job_id]
    assert svc.now >= t_after_first
    assert svc.summary()["jobs"] == 2


def test_service_is_policy_agnostic():
    """Any registry policy gets the same front door."""
    for name in ("fifo", "edf-cold", "elasticflow"):
        svc = PromptTunerService(SimConfig(max_gpus=8), policy=name)
        svc.submit(_req("a", slo=600.0))
        res = svc.run_until_idle()
        assert len(res) == 1 and res[0].completed, name


def test_service_over_sharded_fabric_with_tenants():
    """The service front door over a 2-shard fabric: tenant + SLO class
    on the handle, per-tenant summaries, and streaming callbacks."""
    from repro.api import ClusterFabric, EngineEvent

    fabric = ClusterFabric(SimConfig(max_gpus=16), "prompttuner", shards=2)
    svc = PromptTunerService(fabric=fabric)
    events = []
    svc.stream(events.append)
    handles = []
    for i, (tenant, cls) in enumerate([("acme", "premium"),
                                       ("globex", "standard"),
                                       ("initech", "best-effort")]):
        handles.append(svc.submit(SubmitRequest(
            task_id=f"t{i}", llm="gpt2-base", slo=400.0,
            iters_manual=200, iters_bank=60, submit_time=float(i),
            tenant=tenant, slo_class=cls)))
    h = handles[0]
    assert h.tenant == "acme" and h.slo_class == "premium"
    assert h.effective_slo == pytest.approx(400.0 * 0.75)
    assert h.shard in (0, 1)
    results = svc.run_until_idle()
    assert len(results) == 3 and all(r.completed for r in results)
    assert all(isinstance(e, EngineEvent) for e in events)
    done = [e for e in events if e.kind == "job_done"]
    assert len(done) == 3
    by_tenant = svc.summary_by_tenant()
    for tenant in ("acme", "globex", "initech"):
        assert by_tenant[tenant]["jobs"] == 1
    # premium pays 2x the standard tier per GPU-second
    assert (by_tenant["acme"]["cost_usd"] / by_tenant["acme"]["gpu_seconds"]
            > by_tenant["globex"]["cost_usd"]
            / by_tenant["globex"]["gpu_seconds"])


def test_slo_class_multiplier_affects_routing():
    """Premium tightens the effective SLO, which can push the bank
    lookup out of the §4.4.3 latency budget."""
    svc = PromptTunerService(SimConfig(max_gpus=8))
    prof = LLM_PROFILES["gpt2-base"]
    # just inside the budget at standard stringency, outside at premium
    slo = prof.bank_lookup_s / svc.cfg.latency_budget_frac + 1.0
    std = svc.submit(SubmitRequest(task_id="s", llm="gpt2-base", slo=slo,
                                   iters_manual=200, iters_bank=60))
    prem = svc.submit(SubmitRequest(task_id="p", llm="gpt2-base", slo=slo,
                                    iters_manual=200, iters_bank=60,
                                    slo_class="premium"))
    assert std.routed_through_bank is True
    assert prem.routed_through_bank is False
    with pytest.raises(KeyError, match="unknown SLO class"):
        svc.submit(SubmitRequest(task_id="x", llm="gpt2-base", slo=slo,
                                 iters_manual=200, iters_bank=60,
                                 slo_class="platinum"))


def test_summary_preserves_util_samples():
    """The service's SimResult re-wrap must not drop engine state:
    util_samples (and the tenant ledgers) survive."""
    svc = PromptTunerService(SimConfig(max_gpus=8))
    svc.submit(_req("a", slo=400.0))
    svc.run_until_idle()
    res = svc.sim_result()
    assert len(res.util_samples) > 0
    assert res.util_samples == svc.engine.util_samples
    assert max(g for _, g in res.util_samples) >= 1   # the job actually ran
    assert svc.summary()["jobs"] == 1
    assert "default" in res.gpu_seconds_by_tenant


def test_no_insert_without_tuned_prompt_payload():
    """Requests without a tuned-prompt payload must not mutate the bank
    (lookup still runs off the request feature)."""
    bank, centers = _mk_bank()
    size0 = len(bank)
    svc = PromptTunerService(SimConfig(max_gpus=8), bank=bank,
                             score_fn_factory=_score_factory)
    svc.submit(_req("a", slo=400.0,
                    feature=centers[0].astype(np.float32)))   # no prompt
    res = svc.run_until_idle()
    assert res[0].inserted_to_bank is False
    assert len(bank) == size0
