"""Config registry: every assigned architecture resolves with the exact
assignment numbers; smoke variants respect the reduction bounds."""
import pytest

from repro.config import INPUT_SHAPES
from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, get_config, smoke_config

EXPECT = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "rwkv6-7b": (32, 4096, 0, 0, 14336, 65536),
    "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
    "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
    "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
    "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
    "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
    "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
    # kv = 64: Kimi K2 is DeepSeek-V3-style MLA (latent cache, not GQA),
    # one decompressed KV head per query head — PR 7's decode-kernel work
    # aligned the config with the released architecture
    "kimi-k2-1t-a32b": (61, 7168, 64, 64, 2048, 163840),
}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_assigned_config_exact(arch):
    cfg = get_config(arch)
    L, d, H, kv, dff, V = EXPECT[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.vocab_size == V
    if cfg.arch_type == "ssm":
        assert cfg.attention == "none" or cfg.ssm is not None
        assert cfg.d_ff == dff
    elif cfg.moe is not None:
        assert cfg.moe.d_ff_expert == dff
        assert cfg.num_heads == H and cfg.kv_heads() == kv
    else:
        assert cfg.d_ff == dff
        assert cfg.num_heads == H and cfg.kv_heads() == kv
    assert cfg.source, "every config must cite its source"


def test_moe_details():
    ds = get_config("deepseek-v2-236b")
    assert ds.moe.num_experts == 160 and ds.moe.top_k == 6
    assert ds.moe.num_shared_experts == 2
    assert ds.attention == "mla" and ds.mla.kv_lora_rank == 512
    kimi = get_config("kimi-k2-1t-a32b")
    assert kimi.moe.num_experts == 384 and kimi.moe.top_k == 8
    assert kimi.attention == "mla" and kimi.mla.kv_lora_rank == 512
    assert kimi.mla.qk_rope_head_dim == 64


def test_hybrid_details():
    z = get_config("zamba2-7b")
    assert z.arch_type == "hybrid" and z.ssm.state_size == 64
    assert z.hybrid.shared_attn


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + PAPER_ARCHS)
def test_smoke_reduction_bounds(arch):
    cfg = smoke_config(arch)
    assert cfg.num_layers <= 5
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    assert cfg.arch_type == get_config(arch).arch_type   # same family


def test_input_shapes():
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["decode_32k"].kind == "decode"
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
