"""Integration tests: the dry-run driver end-to-end (subprocess, 512
host devices) and the simulator benchmark paths."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("seamless-m4t-medium", "decode_32k"),
    ("qwen2-7b", "long_500k"),
])
def test_dryrun_subprocess(arch, shape, tmp_path):
    """dryrun.py must lower+compile a full-size config on the 16x16 mesh
    and emit a complete record (own process: it forces 512 devices)."""
    out = os.path.join(tmp_path, "rec.jsonl")
    env = dict(ENV)
    # force the CPU platform (the 512 forced host devices live there):
    # leaving platform autodetection on makes jax probe for a TPU PJRT
    # plugin, whose GCP-metadata fetch can stall for minutes in CI
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--out", out],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.loads(open(out).read().strip())
    assert rec["n_devices"] == 256
    assert rec["roofline"]["dominant"] in ("compute", "memory",
                                           "collective")
    assert rec["hlo_cost"]["flops"] > 0
    assert rec["memory"]["argument_size_in_bytes"] > 0


def test_bench_end2end_quick():
    import benchmarks.bench_end2end as b
    out = b.run_point("low", 1.0, gpus=16, minutes=3, seeds=1)
    assert set(out) == {"prompttuner", "infless", "elasticflow"}
    for r in out.values():
        assert r["cost_usd"] > 0


def test_bench_ablation_direction():
    """The warm-allocator ablation must not IMPROVE SLO attainment."""
    import benchmarks.bench_ablation as b
    full = b._run({}, seeds=1, minutes=5)
    no_alloc = b._run({"use_warm_allocator": False}, seeds=1, minutes=5)
    assert (no_alloc["slo_violation_pct"]
            >= full["slo_violation_pct"] - 1.0)


def test_roofline_table_renders():
    import benchmarks.roofline_table as rt
    recs = rt.load_records("single")
    if not recs:
        pytest.skip("no dry-run artifacts yet")
    rows = rt.rows_for(recs)
    assert len(rows) == len(recs)
    assert all(len(r) == 8 for r in rows)
