"""Fleet telemetry plane: metrics registry semantics, span folding from
scripted event sequences (including a stolen job's shard hop), export
round-trips, elastic-decision audit coverage, and the pin that telemetry
recording never perturbs results."""
import json

import numpy as np
import pytest

from repro.api import PromptTunerService, SubmitRequest
from repro.cluster import (
    ClusterFabric,
    ElasticConfig,
    JOB_STOLEN,
    SHARD_RESIZED,
    SimConfig,
    TenantQuota,
    TraceConfig,
    clone_jobs,
    generate_tenant_mix,
    generate_trace,
)
from repro.cluster.engine import ARRIVAL, JOB_DONE, EngineEvent
from repro.core.jobs import Job
from repro.obs import (
    AuditLog,
    MetricsRegistry,
    Telemetry,
    TimelineRecorder,
    read_jsonl,
    render_report,
    to_chrome_trace,
    validate_chrome_trace,
    write_jsonl,
)
from repro.obs.spans import INIT, QUEUED, REJECTED, RUNNING


def mk_job(jid, llm="gpt2-base", submit=0.0, slo=600.0, tenant="t0"):
    return Job(job_id=jid, llm=llm, submit_time=submit, slo=slo,
               iters_manual=400, iters_bank=200, tenant=tenant)


# -- metrics registry -------------------------------------------------------------


def test_counter_is_monotone_and_label_keyed():
    reg = MetricsRegistry()
    reg.counter("jobs", shard=0).inc()
    reg.counter("jobs", shard=0).inc(2)
    reg.counter("jobs", shard=1).inc()
    assert reg.value("jobs", shard=0) == 3
    assert reg.value("jobs", shard=1) == 1
    assert reg.value("jobs", shard=9) == 0          # absent series reads 0
    assert reg.total("jobs") == 4
    # label ORDER does not split the series
    reg.counter("pair", a=1, b=2).inc()
    reg.counter("pair", b=2, a=1).inc()
    assert reg.value("pair", a=1, b=2) == 2
    with pytest.raises(ValueError, match="only go up"):
        reg.counter("jobs", shard=0).inc(-1)
    # one name, one kind
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("jobs", shard=0)


def test_gauge_tracks_window_excursion():
    reg = MetricsRegistry(window=10.0)
    g = reg.gauge("depth", shard=0)
    g.set(5)
    g.set(1)
    g.set(3)
    assert g.read() == {"value": 3.0, "min": 1.0, "max": 5.0}
    reg.advance(10.0)                               # rolls the window
    assert g.read() == {"value": 3.0, "min": 3.0, "max": 3.0}
    g.add(-2)
    assert g.read()["value"] == 1.0


def test_histogram_log_buckets_and_quantiles():
    reg = MetricsRegistry()
    h = reg.histogram("wait", shard=0)
    assert h.bucket_index(0.0005) == 0              # <= base
    assert h.bucket_index(0.001) == 0
    assert h.bucket_index(0.002) == 1
    assert h.bucket_index(0.004) == 2
    for v in (0.5, 1.0, 2.0, 4.0, 100.0):
        h.observe(v)
    assert h.count == 5
    assert h.sum == pytest.approx(107.5)
    assert h.min == 0.5 and h.max == 100.0
    assert h.mean == pytest.approx(21.5)
    # quantile returns a bucket upper bound >= the true value, <= max
    assert h.quantile(0.5) >= 1.0
    assert h.quantile(1.0) == 100.0
    assert h.quantile(0.0) == 0.0 or h.quantile(0.0) <= 0.5 * 2
    with pytest.raises(ValueError, match=">= 0"):
        h.observe(-1.0)


def test_windowed_snapshots_and_counter_deltas():
    reg = MetricsRegistry(window=60.0)
    reg.counter("done").inc(2)
    reg.advance(60.0)                # captures [0, 60)
    reg.counter("done").inc(3)
    reg.advance(125.0)               # captures [60, 120)
    reg.counter("done").inc(1)
    reg.close()                      # partial [120, 125]
    assert [(w.start, w.end) for w in reg.windows] == [
        (0.0, 60.0), (60.0, 120.0), (120.0, 125.0)]
    assert [w.series["done"]["value"] for w in reg.windows] == [2, 5, 6]
    assert [d for _, _, d in reg.window_deltas("done")] == [2, 3, 1]
    # a jump across several boundaries captures each one
    reg2 = MetricsRegistry(window=10.0)
    reg2.counter("x").inc()
    reg2.advance(35.0)
    assert len(reg2.windows) == 3


# -- span folding from scripted events --------------------------------------------


def test_span_folding_full_lifecycle():
    rec = TimelineRecorder()
    job = mk_job(7, submit=10.0)
    rec.on_event(EngineEvent(ARRIVAL, 10.0, job, shard=2))
    assert rec.timeline(7).spans[-1].end is None    # open queued span
    job.start_time = 40.0
    job.init_overhead = 5.0
    job.gpus = 2
    job.used_bank = True
    rec.on_event(EngineEvent(JOB_DONE, 100.0, job, shard=2))
    tl = rec.timeline(7)
    assert [(s.phase, s.start, s.end) for s in tl.spans] == [
        (QUEUED, 10.0, 40.0), (INIT, 40.0, 45.0), (RUNNING, 45.0, 100.0)]
    assert tl.shard == 2 and tl.done and tl.finish == 100.0
    assert tl.gpus == 2 and tl.used_bank
    assert tl.violated is False                     # slo=600 from t=10
    assert tl.phase_seconds(QUEUED) == 30.0
    assert rec.timeline(999) is None and len(rec) == 1


def test_span_folding_stolen_job_records_shard_hop():
    rec = TimelineRecorder()
    job = mk_job(3)
    rec.on_event(EngineEvent(ARRIVAL, 0.0, job, shard=0))
    # fabric contract: ev.shard on JOB_STOLEN is the RECEIVER
    rec.on_event(EngineEvent(JOB_STOLEN, 50.0, job, shard=1,
                             detail="shard 0 -> 1"))
    job.start_time = 60.0
    job.init_overhead = 0.0
    job.gpus = 1
    rec.on_event(EngineEvent(JOB_DONE, 90.0, job, shard=1))
    tl = rec.timeline(3)
    assert [(h.src, h.dst, h.time) for h in tl.hops] == [(0, 1, 50.0)]
    assert [(s.phase, s.shard, s.start, s.end) for s in tl.spans] == [
        (QUEUED, 0, 0.0, 50.0), (QUEUED, 1, 50.0, 60.0),
        (RUNNING, 1, 60.0, 90.0)]
    assert tl.phase_seconds(QUEUED) == 60.0         # both queued stints


def test_span_folding_rejection_and_roundtrip_dict():
    from repro.cluster.elastic import JOB_REJECTED
    from repro.obs.spans import JobTimeline
    rec = TimelineRecorder()
    rec.on_event(EngineEvent(JOB_REJECTED, 5.0, mk_job(1, submit=5.0),
                             shard=-1, detail="cost cap"))
    tl = rec.timeline(1)
    assert tl.reject_reason == "cost cap" and not tl.done
    assert tl.spans[0].phase == REJECTED and tl.spans[0].duration == 0.0
    back = JobTimeline.from_dict(tl.to_dict())
    assert back.to_dict() == tl.to_dict()


# -- live fabric integration ------------------------------------------------------


def _stealable_fabric():
    return ClusterFabric(SimConfig(max_gpus=8), "prompttuner", shards=2,
                         elastic=ElasticConfig())


def test_telemetry_counters_match_fabric_ground_truth():
    fab = _stealable_fabric()
    events = []
    fab.on_event(events.append)
    tel = Telemetry(window=30.0).attach(fab)
    jobs = [mk_job(i) for i in range(12)]
    res = fab.run(clone_jobs(jobs))
    c = tel.summary_counters()
    assert c["jobs_submitted"] == len(jobs)
    assert c["jobs_completed"] == len(res.records)
    assert c["steals"] == fab.controller.steals > 0
    # the counter counts SHARD_RESIZED events (donor shrink + receiver
    # grow each emit one); controller.resizes counts transfers
    assert c["resizes"] == len([e for e in events
                                if e.kind == SHARD_RESIZED])
    assert c["rounds"] > 0
    # a stolen job's recorded hop matches the event stream
    hopped = [tl for tl in tel.timeline.timelines().values() if tl.hops]
    assert len(hopped) == fab.controller.steals
    # double-attach is loud
    with pytest.raises(ValueError, match="already attached"):
        tel.attach(fab)


def test_audit_carries_shard_health_for_every_elastic_decision():
    # steals: the textbook 2-shard strand; resizes + rejections: the
    # bursty mix under a tight cost cap
    fab = _stealable_fabric()
    events = []
    fab.on_event(events.append)
    tel = Telemetry().attach(fab)
    fab.run(clone_jobs([mk_job(i) for i in range(12)]))

    fab2 = ClusterFabric(SimConfig(max_gpus=16), "prompttuner", shards=2,
                         elastic=ElasticConfig(quotas={
                             "initech": TenantQuota(cost_usd=2.0)}))
    events2 = []
    fab2.on_event(events2.append)
    tel2 = Telemetry().attach(fab2)
    fab2.run(generate_tenant_mix(minutes=6, seed=0))

    for evs, audit in ((events, tel.audit), (events2, tel2.audit)):
        for kind in (JOB_STOLEN, SHARD_RESIZED):
            stream = [e for e in evs if e.kind == kind]
            logged = audit.query(action=kind)
            assert len(stream) == len(logged)
            for e, a in zip(stream, logged):
                assert a.time == e.time and a.shard == e.shard
                assert a.inputs, f"{kind} audit entry missing inputs"
                for h in a.inputs.values():
                    assert {"pressure", "free_capacity", "pending_jobs"
                            } <= set(h)
    assert len(tel.audit.query(action=JOB_STOLEN)) > 0
    assert len(tel2.audit.query(action=SHARD_RESIZED)) > 0
    # rejections carry the whole fleet's health
    rejected = tel2.audit.query(action="job_rejected")
    assert len(rejected) == len(fab2.rejections) > 0
    assert all(len(a.inputs) == 2 for a in rejected)
    # explain() surfaces the nearest decisions around a time
    t = tel2.audit.query(action=SHARD_RESIZED)[0].time
    assert any(e.action == SHARD_RESIZED
               for e in tel2.audit.explain(shard=tel2.audit.query(
                   action=SHARD_RESIZED)[0].shard, t=t))


# -- exports ----------------------------------------------------------------------


def _recorded_run(tmp_path=None):
    fab = _stealable_fabric()
    tel = Telemetry(window=30.0).attach(fab)
    fab.run(clone_jobs([mk_job(i) for i in range(12)]))
    return fab, tel


def test_chrome_trace_is_valid_and_contains_hops():
    fab, tel = _recorded_run()
    tel.metrics.close()
    doc = to_chrome_trace(tel.timeline, tel.metrics, tel.audit,
                          shards=len(fab.shards))
    assert validate_chrome_trace(doc) == []
    json.dumps(doc)                                 # serializable
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert {"X", "M"} <= phases
    assert "i" in phases                            # steal instants
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert all(e["dur"] >= 0 for e in xs)
    names = {e["name"] for e in xs}
    assert {"queued", "running"} <= names
    # corruption is caught
    bad = {"traceEvents": [{"ph": "X", "name": "x", "ts": 1, "pid": 0,
                            "tid": 0, "dur": -5}]}
    assert validate_chrome_trace(bad)


def test_jsonl_round_trip_renders_identical_report(tmp_path):
    _fab, tel = _recorded_run()
    tel.metrics.close()
    path = write_jsonl(str(tmp_path / "run.jsonl"), tel.timeline,
                       tel.metrics, tel.audit)
    loaded = read_jsonl(path)
    assert len(loaded["timelines"]) == len(tel.timeline)
    assert len(loaded["audit"]) == len(tel.audit.entries)
    live = render_report(tel.timeline, tel.metrics.to_dicts(), bucket=30.0)
    replay = render_report(loaded["timelines"], loaded["metrics"],
                           bucket=30.0)
    assert replay == live
    # audit entries survive with their health inputs intact
    by_action = {}
    for a in loaded["audit"]:
        by_action.setdefault(a.action, []).append(a)
    assert set(by_action) == {a.action for a in tel.audit.entries}
    for a in by_action.get(JOB_STOLEN, []):
        assert "src" in a.inputs and "pressure" in a.inputs["src"]


# -- recording must not perturb the simulation ------------------------------------


def test_results_identical_with_telemetry_on_and_off():
    """shards=1 + telemetry attached must stay float-for-float identical
    to the bare run — recording rides the event stream only."""
    jobs = generate_trace(TraceConfig(load="medium", seed=0, minutes=5))
    base = ClusterFabric(SimConfig(max_gpus=16), "prompttuner",
                         shards=1).run(clone_jobs(jobs)).summary()
    fab = ClusterFabric(SimConfig(max_gpus=16), "prompttuner", shards=1)
    tel = Telemetry().attach(fab)
    got = fab.run(clone_jobs(jobs)).summary()
    assert got == base                              # exact, not approx
    assert tel.summary_counters()["jobs_completed"] == len(jobs)
    # elastic multi-shard runs are deterministic under observation too
    e1 = _stealable_fabric().run(
        clone_jobs([mk_job(i) for i in range(12)])).summary()
    fab2 = _stealable_fabric()
    Telemetry().attach(fab2)
    e2 = fab2.run(clone_jobs([mk_job(i) for i in range(12)])).summary()
    assert e1 == e2


# -- service surface --------------------------------------------------------------


def test_service_telemetry_kwarg_and_handle_timeline():
    svc = PromptTunerService(SimConfig(max_gpus=8), telemetry=True)
    assert isinstance(svc.telemetry, Telemetry)
    hs = [svc.submit(SubmitRequest(task_id=f"t{i}", llm="gpt2-base",
                                   slo=600.0, iters_manual=400,
                                   iters_bank=120, submit_time=float(i)))
          for i in range(4)]
    svc.run_until_idle()
    tl = hs[0].timeline()
    assert tl.done and {s.phase for s in tl.spans} >= {QUEUED, RUNNING}
    assert "attainment" in svc.report()
    # off by default: handles raise a pointed error
    svc2 = PromptTunerService(SimConfig(max_gpus=8))
    assert svc2.telemetry is None
    h = svc2.submit(SubmitRequest(task_id="x", llm="gpt2-base", slo=600.0,
                                  iters_manual=400, iters_bank=120))
    with pytest.raises(ValueError, match="telemetry=True"):
        h.timeline()
    with pytest.raises(ValueError, match="telemetry=True"):
        svc2.report()
    # a pre-attached Telemetry on a different fabric is rejected
    other = ClusterFabric(SimConfig(max_gpus=8), "prompttuner", shards=1)
    stray = Telemetry().attach(other)
    with pytest.raises(ValueError, match="different fabric"):
        PromptTunerService(SimConfig(max_gpus=8), telemetry=stray)
