"""SLO forensics + alert rules: scripted attribution units, the
reconciliation invariant (blame sums to overrun), determinism, offline
== live identity, and alert replay at identical sim-times."""
import json

import pytest

from _hypothesis_compat import given, settings, st
from repro.cluster import (
    BURSTY_TENANT_MIX,
    CHAOS_PROFILES,
    ClusterFabric,
    ElasticConfig,
    FaultPlane,
    HazardConfig,
    SimConfig,
    TraceConfig,
    clone_jobs,
    generate_tenant_mix,
    generate_trace,
)
from repro.cluster.elastic import ALERT_FIRED, ALERT_RESOLVED, JOB_STOLEN
from repro.cluster.engine import JOB_DONE, EngineEvent
from repro.cluster.faults import SHARD_SLOWED
from repro.core.jobs import Job
from repro.obs import (
    CAUSES,
    AlertRule,
    AlertRules,
    Telemetry,
    analyze,
    read_jsonl,
)
from repro.obs.alerts import BURN_RATE, QUARANTINE_COUNT, QUEUE_PRESSURE
from repro.obs.audit import AuditEntry
from repro.obs.forensics import EXEC
from repro.obs.spans import INIT, QUEUED, RUNNING, JobTimeline, ShardHop, Span


def mk_tl(job_id=0, submit=0.0, deadline=100.0, violated=True,
          shed_reason=None):
    return JobTimeline(job_id=job_id, task_id="t", llm="gpt2-base",
                       tenant="t0", slo_class="standard",
                       submit_time=submit, deadline=deadline,
                       violated=violated, shed_reason=shed_reason)


def span(tl, phase, shard, start, end, truncated=False):
    tl.spans.append(Span(job_id=tl.job_id, phase=phase, shard=shard,
                         start=start, end=end, truncated=truncated))


# -- scripted attribution units ----------------------------------------------


def test_queue_wait_blame_on_late_completion():
    """50s queued + 10s init + 70s exec vs a 100s deadline: the 30s
    overrun lands on queue_wait (exec and cold_start consume the
    allowance first)."""
    tl = mk_tl(deadline=100.0)
    span(tl, QUEUED, 0, 0.0, 50.0)
    span(tl, INIT, 0, 50.0, 60.0)
    span(tl, RUNNING, 0, 60.0, 130.0)
    rep = analyze([tl])
    jb = rep.job(0)
    assert jb.seconds["queue_wait"] == pytest.approx(50.0)
    assert jb.seconds["cold_start"] == pytest.approx(10.0)
    assert jb.seconds[EXEC] == pytest.approx(70.0)
    assert jb.overrun_s == pytest.approx(30.0)
    assert jb.blame["queue_wait"] == pytest.approx(30.0)
    assert sum(jb.blame.values()) == pytest.approx(jb.overrun_s)
    assert jb.primary_cause == "queue_wait"
    assert rep.totals["queue_wait"] == pytest.approx(30.0)


def test_steal_splits_placement_and_landing_cost_and_indicts():
    """Queued time before a steal indicts the placement; queued time
    after landing is the hop's cost — and the blamed placement seconds
    point at the audit decision that moved the job."""
    tl = mk_tl(deadline=50.0)
    span(tl, QUEUED, 0, 0.0, 20.0)
    tl.hops.append(ShardHop(job_id=0, time=20.0, src=0, dst=1,
                            kind="steal"))
    span(tl, QUEUED, 1, 20.0, 30.0)
    span(tl, INIT, 1, 30.0, 35.0)
    span(tl, RUNNING, 1, 35.0, 200.0)
    audit = [AuditEntry(time=20.0, action=JOB_STOLEN, shard=1, job_id=0,
                        detail="steal 0->1")]
    rep = analyze([tl], audit)
    jb = rep.job(0)
    assert jb.seconds["placement"] == pytest.approx(20.0)
    assert jb.seconds["steal_hop"] == pytest.approx(10.0)
    assert sum(jb.blame.values()) == pytest.approx(jb.overrun_s)
    assert jb.blame["placement"] == pytest.approx(20.0)
    assert jb.indicts is not None and jb.indicts["action"] == JOB_STOLEN


def test_crash_rework_and_retry_backoff():
    """Truncated spans are thrown-away work; the gap to the retry
    re-entry is the recovery policy's backoff."""
    tl = mk_tl(deadline=70.0)
    span(tl, QUEUED, 0, 0.0, 10.0)
    span(tl, INIT, 0, 10.0, 15.0, truncated=True)
    span(tl, RUNNING, 0, 15.0, 40.0, truncated=True)
    tl.hops.append(ShardHop(job_id=0, time=50.0, src=0, dst=1,
                            kind="retry"))
    span(tl, QUEUED, 1, 50.0, 55.0)       # gap 40-50 = backoff
    span(tl, INIT, 1, 55.0, 60.0)
    span(tl, RUNNING, 1, 60.0, 120.0)
    rep = analyze([tl])
    jb = rep.job(0)
    assert jb.seconds["crash_rework"] == pytest.approx(30.0)
    assert jb.seconds["retry_backoff"] == pytest.approx(10.0)
    # a retry hop's landing queue is plain queue_wait, not steal_hop
    assert jb.seconds["steal_hop"] == 0.0
    assert jb.seconds["queue_wait"] == pytest.approx(15.0)
    assert jb.overrun_s == pytest.approx(50.0)
    assert sum(jb.blame.values()) == pytest.approx(50.0)
    assert jb.blame["crash_rework"] == pytest.approx(30.0)
    assert jb.blame["retry_backoff"] == pytest.approx(10.0)


def test_slowdown_tax_rebuilt_from_audited_factor():
    """A shard_slowed audit entry (factor in inputs) splits the final
    attempt into nominal exec + straggler tax."""
    tl = mk_tl(deadline=30.0)
    span(tl, QUEUED, 0, 0.0, 10.0)
    span(tl, INIT, 0, 10.0, 20.0)
    span(tl, RUNNING, 0, 20.0, 60.0)
    audit = [AuditEntry(time=0.0, action=SHARD_SLOWED, shard=0,
                        inputs={"factor": 2.0})]
    rep = analyze([tl], audit)
    jb = rep.job(0)
    # attempt wall = 50s at x2 => 25s tax, 15s nominal running
    assert jb.seconds["slowdown"] == pytest.approx(25.0)
    assert jb.seconds[EXEC] == pytest.approx(15.0)
    assert sum(jb.blame.values()) == pytest.approx(jb.overrun_s)
    assert jb.primary_cause == "slowdown"
    # without the audit log the seconds stay in exec, invariant intact
    jb2 = analyze([tl]).job(0)
    assert jb2.seconds["slowdown"] == 0.0
    assert sum(jb2.blame.values()) == pytest.approx(jb2.overrun_s)


def test_shed_job_blames_entire_observed_lifecycle():
    """A shed job has no finish: every observed second is blamed, even
    when the shed instant precedes the deadline."""
    tl = mk_tl(deadline=500.0, shed_reason="best-effort shed")
    span(tl, QUEUED, 0, 0.0, 80.0, truncated=True)
    rep = analyze([tl])
    jb = rep.job(0)
    assert jb.shed and rep.shed == 1 and rep.completed_late == 0
    assert jb.overrun_s == pytest.approx(80.0)
    assert jb.blame["queue_wait"] == pytest.approx(80.0)


def test_non_violated_and_rejected_jobs_are_excluded():
    ok = mk_tl(job_id=1, violated=False)
    span(ok, RUNNING, 0, 0.0, 10.0)
    rej = mk_tl(job_id=2, violated=None)
    rej.reject_reason = "quota"
    assert analyze([ok, rej]).violated == 0


# -- reconciliation under chaos ----------------------------------------------


def _chaos_run(profile, seed, *, elastic=True):
    jobs = generate_trace(TraceConfig(load="medium", seed=seed, minutes=4))
    faults = FaultPlane(hazard=CHAOS_PROFILES[profile], seed=seed)
    fab = ClusterFabric(
        SimConfig(max_gpus=8, checkpoint_interval_s=30.0), "prompttuner",
        shards=2, elastic=ElasticConfig() if elastic else None,
        faults=faults)
    tel = Telemetry().attach(fab)
    fab.run(clone_jobs(jobs))
    return tel


def _assert_reconciles(rep):
    assert rep.violated > 0, "chaos run produced nothing to blame"
    for jb in rep.jobs:
        assert sum(jb.blame.values()) == pytest.approx(jb.overrun_s,
                                                       abs=1e-6)
        assert sum(jb.seconds.values()) == pytest.approx(
            jb.end - jb.start, abs=1e-6)
        for v in jb.blame.values():
            assert v >= -1e-9


@pytest.mark.parametrize("profile", sorted(CHAOS_PROFILES))
def test_forensics_deterministic_and_reconciles(profile):
    """Same seed + profile => byte-identical report; every job's blame
    sums to its measured overrun."""
    a = _chaos_run(profile, seed=3).forensics()
    b = _chaos_run(profile, seed=3).forensics()
    dump = lambda r: json.dumps(r.to_dict(), sort_keys=True)  # noqa: E731
    assert dump(a) == dump(b)
    _assert_reconciles(a)


def test_forensics_offline_matches_live(tmp_path):
    """analyze() over a reloaded JSONL export reproduces the live
    report byte-for-byte."""
    tel = _chaos_run("mixed", seed=0)
    live = tel.forensics()
    path = tel.export_jsonl(str(tmp_path / "run.jsonl"))
    loaded = read_jsonl(path)
    offline = analyze(loaded["timelines"], loaded["audit"])
    assert json.dumps(live.to_dict(), sort_keys=True, default=float) == \
        json.dumps(offline.to_dict(), sort_keys=True, default=float)


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       crash=st.floats(min_value=0.0, max_value=40.0),
       preempt=st.floats(min_value=0.0, max_value=20.0),
       slow=st.floats(min_value=0.0, max_value=20.0))
def test_blame_sums_to_overrun_under_random_fault_schedules(
        seed, crash, preempt, slow):
    """The reconciliation invariant holds across arbitrary seeded
    hazard schedules — crashes, preemptions, slowdowns, flaps."""
    jobs = generate_trace(TraceConfig(load="medium", seed=seed % 7,
                                      minutes=3))
    hz = HazardConfig(crash_rate=crash, preempt_rate=preempt,
                      slow_rate=slow, flap_rate=8.0,
                      mean_downtime_s=45.0, preempt_lead_s=20.0,
                      flap_period_s=30.0, horizon_s=400.0)
    faults = FaultPlane(hazard=hz, seed=seed)
    fab = ClusterFabric(
        SimConfig(max_gpus=8, checkpoint_interval_s=20.0), "prompttuner",
        shards=2, elastic=ElasticConfig(), faults=faults)
    tel = Telemetry().attach(fab)
    fab.run(clone_jobs(jobs))
    rep = tel.forensics()
    for jb in rep.jobs:
        assert sum(jb.blame.values()) == pytest.approx(jb.overrun_s,
                                                       abs=1e-6)
        assert sum(jb.seconds.values()) == pytest.approx(
            jb.end - jb.start, abs=1e-6)


# -- alert rules --------------------------------------------------------------


def test_alert_rule_validation():
    with pytest.raises(ValueError, match="interval"):
        AlertRules(interval=0.0)
    with pytest.raises(ValueError, match="duplicate"):
        AlertRules([AlertRule("a", BURN_RATE, 2.0),
                    AlertRule("a", QUEUE_PRESSURE, 2.0)])
    with pytest.raises(ValueError, match="unknown rule kind"):
        AlertRules([AlertRule("a", "nope", 2.0)])


def _done(t, job_id, slo):
    job = Job(job_id=job_id, llm="gpt2-base", submit_time=0.0, slo=slo,
              iters_manual=10, iters_bank=10)
    return EngineEvent(kind=JOB_DONE, time=t, job=job, shard=0)


def test_burn_rate_fires_and_resolves():
    """All-violating completions push both windows over threshold; a
    stream of on-time completions brings the short window back down."""
    rules = AlertRules([AlertRule("burn", BURN_RATE, threshold=2.0,
                                  short_s=60.0, long_s=300.0,
                                  target_attainment=0.90)], interval=15.0)
    emitted = []
    rules.bind(emit=emitted.append)
    for i in range(5):
        rules.on_event(_done(10.0 + i, job_id=i, slo=1.0))    # violated
    rules.on_event(_done(30.0, job_id=99, slo=1000.0))
    assert [h.kind for h in rules.history] == [ALERT_FIRED]
    assert rules.history[0].time == pytest.approx(15.0)
    for i in range(40):
        rules.on_event(_done(100.0 + 2 * i, job_id=100 + i, slo=1000.0))
    assert [h.kind for h in rules.history] == [ALERT_FIRED, ALERT_RESOLVED]
    assert rules.active["burn"] is False
    assert [e.kind for e in emitted] == [h.kind for h in rules.history]


def test_quarantine_rule_counts_audit_decisions():
    from repro.cluster.elastic import QUARANTINE

    rules = AlertRules([AlertRule("q", QUARANTINE_COUNT, threshold=2.0,
                                  window_s=100.0)], interval=10.0)

    class FakeAudit:
        entries = [AuditEntry(time=5.0, action=QUARANTINE, shard=0),
                   AuditEntry(time=8.0, action=QUARANTINE, shard=1)]

    rules.bind(audit=FakeAudit())
    rules.on_event(EngineEvent(kind="round", time=12.0, shard=0))
    assert [h.kind for h in rules.history] == [ALERT_FIRED]
    assert rules.history[0].time == pytest.approx(10.0)


def test_controller_tracks_active_alerts():
    """ALERT_* events on the bus land in the controller's active set
    (the hook a future SLO autotuner subscribes through)."""
    fab = ClusterFabric(SimConfig(max_gpus=8), "prompttuner", shards=2,
                        elastic=ElasticConfig())
    fab.announce(EngineEvent(kind=ALERT_FIRED, time=5.0, shard=-1,
                             detail="slo-burn: over budget"))
    assert fab.controller.active_alerts == {"slo-burn": 5.0}
    fab.announce(EngineEvent(kind=ALERT_RESOLVED, time=9.0, shard=-1,
                             detail="slo-burn: back under"))
    assert fab.controller.active_alerts == {}


def test_alert_replay_fires_at_identical_sim_times(tmp_path):
    """Replaying the rules from the exported JSONL reproduces the live
    (time, kind, rule) transition list exactly."""
    jobs = generate_tenant_mix(BURSTY_TENANT_MIX, minutes=10, seed=0)
    faults = FaultPlane(hazard=CHAOS_PROFILES["mixed"], seed=0)
    fab = ClusterFabric(
        SimConfig(max_gpus=16, checkpoint_interval_s=30.0,
                  checkpoint_min_compute_s=180.0), "prompttuner",
        shards=2, elastic=ElasticConfig(), faults=faults)
    alerts = AlertRules()
    tel = Telemetry(alerts=alerts).attach(fab)
    fab.run(clone_jobs(jobs))
    assert alerts.history, "run produced no alerts — pick a harsher mix"
    assert tel.summary_counters()["alerts_fired"] == sum(
        1 for h in alerts.history if h.kind == ALERT_FIRED)

    path = tel.export_jsonl(str(tmp_path / "run.jsonl"))
    loaded = read_jsonl(path)
    replayed = AlertRules().replay(
        loaded["timelines"], loaded["metrics"], loaded["audit"],
        window=tel.metrics.window)
    assert [(h.time, h.kind, h.rule) for h in replayed] == \
        [(h.time, h.kind, h.rule) for h in alerts.history]


def test_alerts_off_by_default_is_inert():
    """Telemetry without AlertRules never emits alert events and the
    run's results stay bit-identical (pinned more broadly in
    test_obs; this guards the counter surface)."""
    tel = _chaos_run("mixed", seed=1)
    c = tel.summary_counters()
    assert c["alerts_fired"] == 0.0 and c["alerts_resolved"] == 0.0


# -- report surface -----------------------------------------------------------


def test_render_mentions_every_cause():
    tel = _chaos_run("mixed", seed=0)
    text = tel.forensics().render()
    for c in CAUSES:
        assert c in text
    assert "violated jobs" in text


def test_cause_shares_sum_to_one_when_any_blame():
    rep = _chaos_run("mixed", seed=0).forensics()
    shares = rep.cause_shares()
    assert sum(shares.values()) == pytest.approx(1.0)
    assert set(shares) == set(CAUSES) | {EXEC}
