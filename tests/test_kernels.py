"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles
(interpret=True on CPU per the brief)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import fused_score_ce, gqa_flash, wkv
from repro.kernels.ref import (
    flash_attention_ref,
    rwkv6_wkv_ref,
    score_ce_ref,
)
from repro.kernels.rwkv_wkv import rwkv6_wkv
from repro.kernels.score_ce import score_ce


# -- score_ce ----------------------------------------------------------------

@pytest.mark.parametrize("T,D,V,bt,bv", [
    (64, 64, 512, 32, 128),
    (100, 128, 1024, 32, 256),       # T not a tile multiple
    (17, 32, 256, 16, 256),          # single vocab tile
    (256, 256, 2048, 128, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_score_ce_sweep(T, D, V, bt, bv, dtype):
    key = jax.random.key(T + D)
    h = jax.random.normal(key, (T, D), dtype)
    e = (jax.random.normal(jax.random.fold_in(key, 1), (V, D)) * 0.05).astype(dtype)
    lab = jax.random.randint(jax.random.fold_in(key, 2), (T,), 0, V)
    out = score_ce(h, e, lab, bt=bt, bv=bv, interpret=True)
    ref = score_ce_ref(h.astype(jnp.float32), e.astype(jnp.float32), lab)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_fused_score_ce_matches_naive(pre_base):
    """Model-layout wrapper vs the framework's naive CE on real data."""
    from repro.data import LoaderConfig, TaskLoader, batch_to_jnp
    from repro.models.common import unembed
    from repro.train.objectives import token_cross_entropy

    pre = pre_base
    loader = TaskLoader(pre.tasks[3], LoaderConfig(batch_size=4))
    b = batch_to_jnp(next(loader))
    hidden, _ = pre.model.backbone(pre.params, b["tokens"])
    mean, per = fused_score_ce(hidden, pre.params["embedding"],
                               b["labels"], b["mask"])
    logits = unembed(pre.model.cfg, pre.params, hidden)
    m2, p2 = token_cross_entropy(logits, b["labels"], b["mask"])
    np.testing.assert_allclose(float(mean), float(m2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(per), np.asarray(p2), rtol=1e-5)


# -- flash attention -----------------------------------------------------------

@pytest.mark.parametrize("B,H,Hkv,S,L,hd,bq,bk", [
    (1, 2, 1, 16, 16, 32, 8, 8),
    (2, 4, 2, 48, 80, 32, 16, 32),
    (1, 8, 1, 33, 130, 64, 16, 64),    # MQA + ragged tiles
    (2, 2, 2, 64, 64, 16, 64, 64),     # single tile
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, Hkv, S, L, hd, bq, bk, dtype):
    key = jax.random.key(B * H + S)
    q = jax.random.normal(key, (B, H, S, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, L, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, L, hd), dtype)
    off = max(L - S, 0)
    out = flash_attention(q, k, v, causal=True, q_offset=off,
                          bq=bq, bk=bk, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, q_offset=off)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [8, 24])
def test_flash_sliding_window(window):
    key = jax.random.key(7)
    q = jax.random.normal(key, (1, 2, 32, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 64, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 64, 16))
    out = flash_attention(q, k, v, causal=True, window=window, q_offset=32,
                          bq=16, bk=16, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=window,
                              q_offset=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_dynamic_kv_len():
    key = jax.random.key(9)
    q = jax.random.normal(key, (1, 2, 8, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 64, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 64, 16))
    for kv_len in (8, 33, 64):
        out = flash_attention(q, k, v, causal=False, kv_len=kv_len,
                              bq=8, bk=16, interpret=True)
        ref = flash_attention_ref(q, k, v, causal=False, kv_len=kv_len)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


def test_gqa_flash_model_layout_matches_model_attention():
    """ops.gqa_flash must agree with the XLA attention the models use."""
    from repro.models.attention import scaled_attention

    key = jax.random.key(3)
    B, S, H, Hkv, hd = 2, 24, 4, 2, 32
    q = jax.random.normal(key, (B, S, Hkv, H // Hkv, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    ref = scaled_attention(q, k, v, q_pos=pos, kv_pos=pos, causal=True)
    qm = q.reshape(B, S, H, hd)
    out = gqa_flash(qm, k, v, causal=True, bq=8, bk=8)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.reshape(B, S, H, hd)),
        rtol=2e-5, atol=2e-5)


# -- rwkv wkv --------------------------------------------------------------------

@pytest.mark.parametrize("BH,T,hd,chunk", [
    (2, 32, 16, 8),
    (3, 50, 16, 16),      # ragged chunks
    (1, 128, 64, 32),
    (4, 17, 8, 32),       # chunk > T
])
def test_rwkv_wkv_sweep(BH, T, hd, chunk):
    key = jax.random.key(BH * T)
    r = jax.random.normal(key, (BH, T, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (BH, T, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (BH, T, hd))
    logw = jnp.maximum(-jnp.exp(
        jax.random.normal(jax.random.fold_in(key, 3), (BH, T, hd))), -8.0)
    u = jax.random.normal(jax.random.fold_in(key, 4), (BH, hd)) * 0.5
    s0 = jax.random.normal(jax.random.fold_in(key, 5), (BH, hd, hd)) * 0.3
    y, s = rwkv6_wkv(r, k, v, logw, u, s0, chunk=chunk, interpret=True)
    yr, sr = rwkv6_wkv_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=2e-4, atol=2e-4)


def test_wkv_matches_model_rwkv_chunk():
    """The kernel must agree with the model's XLA chunked scan
    (ssm._rwkv6_chunk composed over chunks)."""
    from repro.models import ssm as ssm_mod

    key = jax.random.key(11)
    B, H, T, hd = 1, 2, 32, 16
    r = jax.random.normal(key, (B, H, T, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, T, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, T, hd))
    logw = jnp.maximum(-jnp.exp(
        jax.random.normal(jax.random.fold_in(key, 3), (B, H, T, hd))), -8.0)
    u = jax.random.normal(jax.random.fold_in(key, 4), (H, hd)) * 0.5
    s0 = jnp.zeros((B, H, hd, hd))
    y_kernel, s_kernel = wkv(r, k, v, logw, u, s0, chunk=8)
    y_model, s_model = ssm_mod._rwkv6_chunk(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_kernel), np.asarray(s_model),
                               rtol=2e-4, atol=2e-4)
