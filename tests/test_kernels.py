"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles
(interpret=True on CPU per the brief)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.mla_decode import mla_decode
from repro.kernels.ops import (
    fused_score_ce,
    gqa_flash,
    gqa_flash_decode,
    mla_flash_decode,
    wkv,
)
from repro.kernels.ref import (
    flash_attention_ref,
    flash_decode_ref,
    mla_decode_ref,
    rwkv6_wkv_ref,
    score_ce_ref,
)
from repro.kernels.rwkv_wkv import rwkv6_wkv
from repro.kernels.score_ce import score_ce


# -- score_ce ----------------------------------------------------------------

@pytest.mark.parametrize("T,D,V,bt,bv", [
    (64, 64, 512, 32, 128),
    (100, 128, 1024, 32, 256),       # T not a tile multiple
    (17, 32, 256, 16, 256),          # single vocab tile
    (256, 256, 2048, 128, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_score_ce_sweep(T, D, V, bt, bv, dtype):
    key = jax.random.key(T + D)
    h = jax.random.normal(key, (T, D), dtype)
    e = (jax.random.normal(jax.random.fold_in(key, 1), (V, D)) * 0.05).astype(dtype)
    lab = jax.random.randint(jax.random.fold_in(key, 2), (T,), 0, V)
    out = score_ce(h, e, lab, bt=bt, bv=bv, interpret=True)
    ref = score_ce_ref(h.astype(jnp.float32), e.astype(jnp.float32), lab)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_fused_score_ce_matches_naive(pre_base):
    """Model-layout wrapper vs the framework's naive CE on real data."""
    from repro.data import LoaderConfig, TaskLoader, batch_to_jnp
    from repro.models.common import unembed
    from repro.train.objectives import token_cross_entropy

    pre = pre_base
    loader = TaskLoader(pre.tasks[3], LoaderConfig(batch_size=4))
    b = batch_to_jnp(next(loader))
    hidden, _ = pre.model.backbone(pre.params, b["tokens"])
    mean, per = fused_score_ce(hidden, pre.params["embedding"],
                               b["labels"], b["mask"])
    logits = unembed(pre.model.cfg, pre.params, hidden)
    m2, p2 = token_cross_entropy(logits, b["labels"], b["mask"])
    np.testing.assert_allclose(float(mean), float(m2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(per), np.asarray(p2), rtol=1e-5)


# -- flash attention -----------------------------------------------------------

@pytest.mark.parametrize("B,H,Hkv,S,L,hd,bq,bk", [
    (1, 2, 1, 16, 16, 32, 8, 8),
    (2, 4, 2, 48, 80, 32, 16, 32),
    (1, 8, 1, 33, 130, 64, 16, 64),    # MQA + ragged tiles
    (2, 2, 2, 64, 64, 16, 64, 64),     # single tile
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, Hkv, S, L, hd, bq, bk, dtype):
    key = jax.random.key(B * H + S)
    q = jax.random.normal(key, (B, H, S, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, L, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, L, hd), dtype)
    off = max(L - S, 0)
    out = flash_attention(q, k, v, causal=True, q_offset=off,
                          bq=bq, bk=bk, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, q_offset=off)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [8, 24])
def test_flash_sliding_window(window):
    key = jax.random.key(7)
    q = jax.random.normal(key, (1, 2, 32, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 64, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 64, 16))
    out = flash_attention(q, k, v, causal=True, window=window, q_offset=32,
                          bq=16, bk=16, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=window,
                              q_offset=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_dynamic_kv_len():
    key = jax.random.key(9)
    q = jax.random.normal(key, (1, 2, 8, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 64, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 64, 16))
    for kv_len in (8, 33, 64):
        out = flash_attention(q, k, v, causal=False, kv_len=kv_len,
                              bq=8, bk=16, interpret=True)
        ref = flash_attention_ref(q, k, v, causal=False, kv_len=kv_len)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


def test_gqa_flash_model_layout_matches_model_attention():
    """ops.gqa_flash must agree with the XLA attention the models use."""
    from repro.models.attention import scaled_attention

    key = jax.random.key(3)
    B, S, H, Hkv, hd = 2, 24, 4, 2, 32
    q = jax.random.normal(key, (B, S, Hkv, H // Hkv, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    ref = scaled_attention(q, k, v, q_pos=pos, kv_pos=pos, causal=True)
    qm = q.reshape(B, S, H, hd)
    out = gqa_flash(qm, k, v, causal=True, bq=8, bk=8)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.reshape(B, S, H, hd)),
        rtol=2e-5, atol=2e-5)


# -- gqa_flash ergonomics ----------------------------------------------------

def test_gqa_flash_rejects_oversized_head_dim():
    """hd > 256 must raise a clear ValueError, not a Mosaic shape error
    from inside the Pallas call."""
    q = jnp.zeros((1, 8, 2, 512))
    k = v = jnp.zeros((1, 8, 2, 512))
    with pytest.raises(ValueError, match="head_dim=512"):
        gqa_flash(q, k, v)
    with pytest.raises(ValueError, match="head_dim=512"):
        gqa_flash_decode(jnp.zeros((1, 2, 512)), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3))


@pytest.mark.parametrize("L", [130, 200, 100])
def test_gqa_flash_pads_non_128_multiple_kv(L):
    """KV lengths that aren't lane multiples are zero-padded + masked;
    the result must still match the unpadded XLA oracle."""
    key = jax.random.key(L)
    B, S, H, Hkv, hd = 1, 16, 4, 2, 32
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, L, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, L, Hkv, hd))
    off = L - S
    out = gqa_flash(q, k, v, causal=True, q_offset=off)
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3),
                              causal=True, q_offset=off)
    np.testing.assert_allclose(np.asarray(out.transpose(0, 2, 1, 3)),
                               np.asarray(ref), rtol=2e-5, atol=2e-5)
    # a caller-supplied kv_len tighter than L must survive the padding
    out = gqa_flash(q, k, v, causal=False, kv_len=L - 7)
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3),
                              causal=False, kv_len=L - 7)
    np.testing.assert_allclose(np.asarray(out.transpose(0, 2, 1, 3)),
                               np.asarray(ref), rtol=2e-5, atol=2e-5)


# -- flash decode (split-KV) -------------------------------------------------

@pytest.mark.parametrize("B,H,Hkv,hd,L,splits,bk", [
    (1, 4, 4, 32, 64, 2, 32),          # MHA (G=1)
    (2, 8, 2, 64, 200, 4, 64),         # GQA 4, ragged partitions
    (1, 16, 2, 32, 256, 8, 32),        # GQA 8, many splits
    (2, 8, 1, 64, 96, 16, 32),         # MQA, splits > L/bk (clamped)
    (1, 28, 4, 128, 320, 4, 128),      # qwen2-7b head geometry
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, H, Hkv, hd, L, splits, bk, dtype):
    key = jax.random.key(B * H + L)
    q = jax.random.normal(key, (B, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, L, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, L, hd), dtype)
    out = flash_decode(q, k, v, splits=splits, bk=bk, interpret=True)
    ref = flash_decode_ref(q, k, v)
    tol = 1e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("kv_len", [1, 7, 64, 129, 200])
def test_flash_decode_ragged_kv_len(kv_len):
    """Dynamic cache lengths, including ones that leave whole partitions
    empty (their LSE combine weight must be exactly 0)."""
    key = jax.random.key(kv_len)
    B, H, Hkv, hd, L = 2, 8, 2, 32, 200
    q = jax.random.normal(key, (B, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, L, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, L, hd))
    out = flash_decode(q, k, v, kv_len=kv_len, splits=4, bk=32,
                       interpret=True)
    ref = flash_decode_ref(q, k, v, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_flash_decode_matches_flash_attention_at_s1():
    """The decode kernel must agree with the prefill flash kernel run at
    S=1 with the matching q_offset (the ISSUE's S=1 parity gate)."""
    key = jax.random.key(17)
    B, H, Hkv, hd, L = 2, 8, 2, 64, 160
    q = jax.random.normal(key, (B, H, 1, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, L, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, L, hd))
    for kv_len in (40, 160):
        dec = flash_decode(q[:, :, 0], k, v, kv_len=kv_len, splits=4,
                           bk=32, interpret=True)
        pre = flash_attention(q, k, v, causal=True, q_offset=kv_len - 1,
                              kv_len=kv_len, bq=8, bk=32, interpret=True)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(pre[:, :, 0]),
                                   rtol=1e-3, atol=1e-3)
        ref = flash_attention_ref(q, k, v, causal=True, q_offset=kv_len - 1,
                                  kv_len=kv_len)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(ref[:, :, 0]),
                                   rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("window", [16, 48])
def test_flash_decode_sliding_window(window):
    key = jax.random.key(window)
    B, H, Hkv, hd, L = 1, 4, 2, 32, 128
    q = jax.random.normal(key, (B, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, L, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, L, hd))
    out = flash_decode(q, k, v, kv_len=100, window=window, splits=4, bk=32,
                       interpret=True)
    ref = flash_decode_ref(q, k, v, kv_len=100, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_gqa_decode_model_wiring_matches_xla_path():
    """models.attention.gqa_decode(use_flash=True) must reproduce the
    XLA cache path bit-for-tolerance over a multi-step decode."""
    from repro.configs import smoke_config
    from repro.models import attention as attn
    from repro.models import build_model

    cfg = smoke_config("qwen2-7b")
    model = build_model(cfg)
    p = jax.tree.map(lambda t: t[0],
                     model.init(jax.random.key(0))["blocks"]["attn"])
    B = 2
    c_xla = c_flash = attn.gqa_init_cache(cfg, B, 32, jnp.float32)
    for t in range(4):
        xt = jax.random.normal(jax.random.key(100 + t), (B, 1, cfg.d_model))
        y1, c_xla = attn.gqa_decode(cfg, p, xt, c_xla, jnp.int32(t),
                                    use_flash=False)
        y2, c_flash = attn.gqa_decode(cfg, p, xt, c_flash, jnp.int32(t),
                                      use_flash=True)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-4)


# -- mla decode (absorbed latent) --------------------------------------------

# (qk_nope, qk_rope, kv_lora, H): scaled sweep + the real deepseek-v2 /
# kimi-k2 latent dims (kv_lora_rank=512, rope=64) at reduced head count
MLA_DIMS = [
    (32, 16, 64, 8),
    (64, 32, 128, 16),
    (128, 64, 512, 8),      # deepseek-v2 / kimi-k2 latent geometry
]


@pytest.mark.parametrize("nope,rope,r,H", MLA_DIMS)
@pytest.mark.parametrize("kv_len", [1, 37, 96])
def test_mla_decode_sweep(nope, rope, r, H, kv_len):
    key = jax.random.key(nope + kv_len)
    B, L = 2, 96
    scale = 1.0 / np.sqrt(nope + rope)
    ql = jax.random.normal(key, (B, H, r)) * 0.1
    qp = jax.random.normal(jax.random.fold_in(key, 1), (B, H, rope))
    ckv = jax.random.normal(jax.random.fold_in(key, 2), (B, L, r)) * 0.1
    kpe = jax.random.normal(jax.random.fold_in(key, 3), (B, L, rope))
    out = mla_decode(ql, qp, ckv, kpe, scale=scale, kv_len=kv_len,
                     splits=4, bk=32, interpret=True)
    ref = mla_decode_ref(ql, qp, ckv, kpe, scale=scale, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_mla_decode_model_wiring_matches_xla_path():
    """models.attention.mla_decode(use_flash=True) vs the dense latent
    path, multi-step, on the deepseek smoke config."""
    from repro.configs import smoke_config
    from repro.models import attention as attn
    from repro.models import build_model

    cfg = smoke_config("deepseek-v2-236b")
    model = build_model(cfg)
    p = jax.tree.map(lambda t: t[0],
                     model.init(jax.random.key(0))["dense0"]["attn"])
    B = 2
    c_xla = c_flash = attn.mla_init_cache(cfg, B, 32, jnp.float32)
    for t in range(4):
        xt = jax.random.normal(jax.random.key(200 + t), (B, 1, cfg.d_model))
        y1, c_xla = attn.mla_decode(cfg, p, xt, c_xla, jnp.int32(t),
                                    use_flash=False)
        y2, c_flash = attn.mla_decode(cfg, p, xt, c_flash, jnp.int32(t),
                                      use_flash=True)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-4)


def test_decode_wrappers_model_layout():
    """ops wrappers accept the (B,1,...) model layout and round-trip it."""
    key = jax.random.key(5)
    B, H, Hkv, hd, L = 1, 8, 2, 32, 64
    q = jax.random.normal(key, (B, 1, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, L, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, L, Hkv, hd))
    out = gqa_flash_decode(q, k, v, kv_len=50)
    assert out.shape == (B, 1, H, hd)
    ref = flash_decode_ref(q[:, 0], k.transpose(0, 2, 1, 3),
                           v.transpose(0, 2, 1, 3), kv_len=50)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)

    r, rope = 64, 16
    ql = jax.random.normal(jax.random.fold_in(key, 3), (B, 1, H, r))
    qp = jax.random.normal(jax.random.fold_in(key, 4), (B, 1, H, rope))
    ckv = jax.random.normal(jax.random.fold_in(key, 5), (B, L, r))
    kpe = jax.random.normal(jax.random.fold_in(key, 6), (B, L, rope))
    out = mla_flash_decode(ql, qp, ckv, kpe, scale=0.1, kv_len=50)
    assert out.shape == (B, 1, H, r)
    ref = mla_decode_ref(ql[:, 0], qp[:, 0], ckv, kpe, scale=0.1, kv_len=50)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_decode_roofline_traffic_below_xla_baseline():
    """The modeled per-step HBM traffic of the fused decode kernels must
    beat the naive XLA path on every priced arch config, and the memory
    roofline term must shrink accordingly."""
    from repro.configs import get_config
    from repro.roofline import (
        gqa_decode_hbm_bytes,
        mla_decode_hbm_bytes,
        roofline_terms,
    )

    for arch in ("qwen2-7b", "phi3-medium-14b", "command-r-plus-104b"):
        cfg = get_config(arch)
        t = gqa_decode_hbm_bytes(B=8, H=cfg.num_heads, Hkv=cfg.kv_heads(),
                                 hd=cfg.resolved_head_dim(), L=16384)
        assert t["fused_bytes"] < t["naive_bytes"], arch
        assert t["fused_bytes"] >= t["floor_bytes"], arch
        naive = roofline_terms(t["flops"], t["naive_bytes"], 0.0)
        fused = roofline_terms(t["flops"], t["fused_bytes"], 0.0)
        assert fused["memory_s"] < naive["memory_s"], arch
        assert fused["dominant"] == "memory", arch     # decode stays HBM-bound

    for arch in ("deepseek-v2-236b", "kimi-k2-1t-a32b"):
        m = get_config(arch).mla
        t = mla_decode_hbm_bytes(B=8, H=get_config(arch).num_heads,
                                 r=m.kv_lora_rank, rd=m.qk_rope_head_dim,
                                 L=16384)
        assert t["fused_bytes"] < t["naive_bytes"], arch
        assert t["fused_bytes"] >= t["floor_bytes"], arch


# -- rwkv wkv --------------------------------------------------------------------

@pytest.mark.parametrize("BH,T,hd,chunk", [
    (2, 32, 16, 8),
    (3, 50, 16, 16),      # ragged chunks
    (1, 128, 64, 32),
    (4, 17, 8, 32),       # chunk > T
])
def test_rwkv_wkv_sweep(BH, T, hd, chunk):
    key = jax.random.key(BH * T)
    r = jax.random.normal(key, (BH, T, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (BH, T, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (BH, T, hd))
    logw = jnp.maximum(-jnp.exp(
        jax.random.normal(jax.random.fold_in(key, 3), (BH, T, hd))), -8.0)
    u = jax.random.normal(jax.random.fold_in(key, 4), (BH, hd)) * 0.5
    s0 = jax.random.normal(jax.random.fold_in(key, 5), (BH, hd, hd)) * 0.3
    y, s = rwkv6_wkv(r, k, v, logw, u, s0, chunk=chunk, interpret=True)
    yr, sr = rwkv6_wkv_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=2e-4, atol=2e-4)


def test_wkv_matches_model_rwkv_chunk():
    """The kernel must agree with the model's XLA chunked scan
    (ssm._rwkv6_chunk composed over chunks)."""
    from repro.models import ssm as ssm_mod

    key = jax.random.key(11)
    B, H, T, hd = 1, 2, 32, 16
    r = jax.random.normal(key, (B, H, T, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, T, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, T, hd))
    logw = jnp.maximum(-jnp.exp(
        jax.random.normal(jax.random.fold_in(key, 3), (B, H, T, hd))), -8.0)
    u = jax.random.normal(jax.random.fold_in(key, 4), (H, hd)) * 0.5
    s0 = jnp.zeros((B, H, hd, hd))
    y_kernel, s_kernel = wkv(r, k, v, logw, u, s0, chunk=8)
    y_model, s_model = ssm_mod._rwkv6_chunk(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_kernel), np.asarray(s_model),
                               rtol=2e-4, atol=2e-4)
