"""Loss-path equivalences + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.config import ModelConfig
from repro.models import build_model
from repro.train.objectives import (
    chunked_token_cross_entropy,
    lpt_loss,
    lpt_loss_chunked,
    token_cross_entropy,
)

CFG = ModelConfig(num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                  head_dim=16, d_ff=64, vocab_size=64, dtype="float32",
                  param_dtype="float32", remat=False)


@settings(max_examples=20, deadline=None)
@given(
    B=st.integers(1, 4),
    S=st.integers(2, 20),
    chunk=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
def test_chunked_ce_equals_naive(B, S, chunk, seed):
    """Property: the chunked CE path is exactly the naive CE for every
    shape/chunking, including ragged chunks and partial masks."""
    model = build_model(CFG)
    key = jax.random.key(seed)
    hidden = jax.random.normal(key, (B, S, CFG.d_model))
    labels = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                                CFG.vocab_size)
    mask = (jax.random.uniform(jax.random.fold_in(key, 2), (B, S)) >
            0.3).astype(jnp.float32)
    params = model.init(jax.random.fold_in(key, 3))
    from repro.models.common import unembed
    logits = unembed(CFG, params, hidden)
    m1, p1 = token_cross_entropy(logits, labels, mask)
    m2, p2 = chunked_token_cross_entropy(model, params, hidden, labels,
                                         mask, chunk=chunk)
    np.testing.assert_allclose(float(m1), float(m2), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                               rtol=1e-5, atol=1e-6)


def test_lpt_loss_chunked_equals_naive():
    model = build_model(CFG)
    params = model.init(jax.random.key(0))
    B, S, P = 2, 12, 4
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (B, S), 0,
                                     CFG.vocab_size),
        "labels": jax.random.randint(jax.random.key(2), (B, S), 0,
                                     CFG.vocab_size),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    prompt = jax.random.normal(jax.random.key(3), (P, CFG.d_model))
    t1, (l1, _) = lpt_loss(model, params, prompt, batch, P)
    t2, (l2, _) = lpt_loss_chunked(model, params, prompt, batch, chunk=5)
    np.testing.assert_allclose(float(t1), float(t2), rtol=1e-5)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_masked_positions_do_not_contribute():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.array([[1, 2, 3, 4]])
    m_all, _ = token_cross_entropy(logits, labels, jnp.ones((1, 4)))
    m_half, _ = token_cross_entropy(logits, labels,
                                    jnp.array([[1.0, 1.0, 0.0, 0.0]]))
    np.testing.assert_allclose(float(m_all), float(m_half), rtol=1e-6)
    assert abs(float(m_all) - np.log(8)) < 1e-5
