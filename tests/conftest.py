import os
import sys

# tests must see ONE device (the dry-run sets 512 itself, in its own proc)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest

from repro.train.pretrain import pretrain


@pytest.fixture(scope="session")
def pre_base():
    """Pretrained testbed artifact (cached under artifacts/)."""
    return pretrain("gpt2-base", cache=True)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
