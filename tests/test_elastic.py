"""Elastic control plane: work stealing, autoscaling, tenant quotas,
engine resize/extract/inject verbs, and the conservation properties the
fabric must keep through all of them."""
import numpy as np
import pytest

from repro.api import PromptTunerService, SubmitRequest
from repro.cluster import (
    BURSTY_TENANT_MIX,
    ClusterFabric,
    ElasticConfig,
    JOB_REJECTED,
    JOB_STOLEN,
    SHARD_RESIZED,
    SimConfig,
    TenantQuota,
    TraceConfig,
    clone_jobs,
    fleet_health,
    generate_tenant_mix,
    generate_trace,
    policies,
)
from repro.cluster.engine import ARRIVAL, JOB_DONE, ROUND
from repro.core.jobs import Job


def mk_job(jid, llm="gpt2-base", submit=0.0, slo=600.0, tenant="t0",
           iters_manual=400, iters_bank=200):
    return Job(job_id=jid, llm=llm, submit_time=submit, slo=slo,
               iters_manual=iters_manual, iters_bank=iters_bank,
               tenant=tenant)


# -- work stealing ---------------------------------------------------------------


def _stealable_fabric():
    """2 shards x 4 GPUs; llm-affinity strands every gpt2-base job on
    one shard while the other idles — the textbook steal setup."""
    return ClusterFabric(SimConfig(max_gpus=8), "prompttuner", shards=2,
                         elastic=ElasticConfig())


def test_steal_moves_overflow_to_idle_shard():
    fab = _stealable_fabric()
    events = []
    fab.on_event(events.append)
    jobs = [mk_job(i) for i in range(12)]
    res = fab.run(clone_jobs(jobs))
    stolen = [e for e in events if e.kind == JOB_STOLEN]
    assert fab.controller.steals == len(stolen) > 0
    # the receiving shard really ran the stolen jobs
    src = {e.detail.split()[1] for e in stolen}
    dst = {e.shard for e in stolen}
    assert all(e.detail.startswith("shard ") for e in stolen)
    assert src and all(int(s) not in dst for s in src)
    for eng_idx in dst:
        assert fab.shards[eng_idx].records, "steal destination never ran"
    # stealing must help: with generous SLOs everything completes
    assert len(res.records) == len(jobs)
    assert all(np.isfinite(r.finish) for r in res.records)


def test_conservation_every_job_exactly_one_shard_one_done():
    """Property (incl. after steals): each submitted job finishes on
    exactly one shard, with exactly one JOB_DONE event and one record."""
    fab = _stealable_fabric()
    events = []
    fab.on_event(events.append)
    jobs = [mk_job(i) for i in range(16)]
    res = fab.run(clone_jobs(jobs))
    done = [e for e in events if e.kind == JOB_DONE]
    assert sorted(e.job.job_id for e in done) == [j.job_id for j in jobs]
    assert sorted(r.job.job_id for r in res.records) == [
        j.job_id for j in jobs]
    per_shard = [{r.job.job_id for r in eng.records} for eng in fab.shards]
    assert not (per_shard[0] & per_shard[1])
    # placed map tracks the final home of every stolen job
    for e in done:
        assert fab.placed[e.job.job_id] == e.shard


def test_steal_respects_replica_feasibility():
    """A 4-GPU-replica job must never be stolen onto a shard too small
    to ever hold one replica."""
    # 10 GPUs over 3 shards -> 4/3/3: only shard 0 fits llama-30b
    fab = ClusterFabric(SimConfig(max_gpus=10), "prompttuner", shards=3,
                        elastic=ElasticConfig())
    jobs = [mk_job(i, llm="llama-30b", slo=4000.0, iters_manual=50,
                   iters_bank=25) for i in range(4)]
    res = fab.run(clone_jobs(jobs))
    assert all(r.job.job_id in {r2.job.job_id for r2 in fab.shards[0].records}
               for r in res.records)
    assert fab.controller.steals == 0


def test_migrate_refuses_missing_or_running_jobs():
    fab = ClusterFabric(SimConfig(max_gpus=8), "prompttuner", shards=2)
    assert fab.migrate(999, 1) is False          # never submitted
    j = mk_job(0)
    fab.submit(j)
    assert fab.migrate(0, fab.placed[0]) is False  # same-shard no-op
    fab.run()
    assert fab.migrate(0, 1 - fab.placed[0]) is False  # already done


# -- autoscaling -----------------------------------------------------------------


def test_autoscale_conserves_fleet_and_emits_events():
    jobs = generate_tenant_mix(BURSTY_TENANT_MIX, minutes=5, seed=0)
    fab = ClusterFabric(SimConfig(max_gpus=32), "prompttuner", shards=8,
                        elastic=ElasticConfig())
    events = []
    fab.on_event(events.append)
    fab.run(clone_jobs(jobs))
    resized = [e for e in events if e.kind == SHARD_RESIZED]
    assert fab.controller.resizes > 0 and resized
    assert all("->" in e.detail for e in resized)
    # every donated GPU landed on a receiver: fleet total is conserved
    assert sum(e.cfg.max_gpus for e in fab.shards) == 32


def test_engine_resize_grow_and_clamped_shrink():
    eng = policies.build("prompttuner", SimConfig(max_gpus=8))
    assert eng.resize(12) == 12
    assert eng.cold_free == 12
    # shrink below the cold pool is clamped to what is actually free
    eng.run([mk_job(0, iters_manual=100, iters_bank=50)])
    warm = sum(p.total() for p in eng.pools.values())
    assert warm > 0
    got = eng.resize(0)
    assert got == warm                   # only cold GPUs were revocable
    assert eng.cold_free == 0


def test_admit_at_rearms_a_drained_engine():
    eng = policies.build("prompttuner", SimConfig(max_gpus=4))
    eng.begin([mk_job(0, iters_manual=100, iters_bank=50)])
    while eng.step():
        pass
    assert eng.next_event_time() is None         # fully drained
    late = mk_job(1, submit=eng.now, iters_manual=100, iters_bank=50)
    eng.admit_at(late, eng.now + 5.0)
    while eng.step():
        pass
    assert {r.job.job_id for r in eng.records} == {0, 1}
    assert all(np.isfinite(r.finish) for r in eng.records)


def test_extract_pending_removes_exactly_one():
    eng = policies.build("prompttuner", SimConfig(max_gpus=1))
    eng.begin([mk_job(0, iters_manual=2000, iters_bank=1000),
               mk_job(1, iters_manual=2000, iters_bank=1000)])
    while eng.step() and len(eng.pending_jobs()) != 1:
        pass
    assert len(eng.pending_jobs()) == 1
    pending_id = eng.pending_jobs()[0].job_id
    before = eng.outstanding_jobs
    job = eng.extract_pending(pending_id)
    assert job is not None and job.job_id == pending_id
    assert eng.pending_jobs() == []
    assert eng.outstanding_jobs == before - 1
    assert eng.extract_pending(pending_id) is None


def test_shard_health_pressure_signals():
    eng = policies.build("prompttuner", SimConfig(max_gpus=4))
    h = fleet_health([eng])[0]
    assert h.pressure == 0.0 and h.free_capacity == 4
    eng.begin([mk_job(i) for i in range(8)])
    for _ in range(20):
        eng.step()
    h = fleet_health([eng])[0]
    assert h.pressure > 1.0              # 8 single-GPU jobs on 4 GPUs
    assert h.pending_jobs + len(eng.running) == 8


# -- tenant quotas ----------------------------------------------------------------


def test_quota_max_outstanding_rejects_with_typed_event():
    fab = ClusterFabric(
        SimConfig(max_gpus=8), "prompttuner", shards=2,
        elastic=ElasticConfig(quotas={"t0": TenantQuota(max_outstanding=2)}))
    events = []
    fab.on_event(events.append)
    assert fab.submit(mk_job(0)) >= 0
    assert fab.submit(mk_job(1)) >= 0
    assert fab.submit(mk_job(2)) == -1
    rej = [e for e in events if e.kind == JOB_REJECTED]
    assert len(rej) == 1 and rej[0].job.job_id == 2 and rej[0].shard == -1
    assert "outstanding" in rej[0].detail
    assert len(fab.rejections) == 1
    res = fab.run()
    # the rejected job never ran and never billed
    assert sorted(r.job.job_id for r in res.records) == [0, 1]
    # other tenants are unaffected
    assert fab.submit(mk_job(3, tenant="other")) >= 0


def test_quota_cost_cap_rejects_before_placement():
    fab = ClusterFabric(
        SimConfig(max_gpus=8), "prompttuner", shards=2,
        elastic=ElasticConfig(quotas={"t0": TenantQuota(cost_usd=1e-6)}))
    assert fab.submit(mk_job(0)) == -1
    assert "cost cap" in fab.rejections[0][1]
    assert fab.placed == {}


def test_quota_gpu_second_budget_tracks_completed_spend():
    quota = TenantQuota(gpu_seconds=200.0)
    fab = ClusterFabric(
        SimConfig(max_gpus=4), "prompttuner", shards=2,
        elastic=ElasticConfig(quotas={"t0": quota}))
    # ~60 s of single-GPU work fits the 200 GPU-s budget...
    assert fab.submit(mk_job(0, iters_manual=500, iters_bank=250)) >= 0
    fab.run()
    spent = fab.controller.tenant_commitment("t0")[0]
    assert spent > 0
    # ...but once completed spend is on the ledger, a job whose estimate
    # overflows the remainder is rejected
    big = mk_job(1, submit=fab.now, iters_manual=3000, iters_bank=1500)
    assert fab.submit(big) == -1
    assert "budget" in fab.rejections[0][1]


def test_service_surfaces_rejection_on_handle():
    svc = PromptTunerService(
        SimConfig(max_gpus=8), shards=2,
        elastic=ElasticConfig(quotas={"acme": TenantQuota(max_outstanding=1)}))
    req = SubmitRequest(task_id="t", llm="gpt2-base", slo=600.0,
                        iters_manual=300, iters_bank=150, tenant="acme")
    h1 = svc.submit(req)
    assert not h1.rejected and h1.shard >= 0
    h2 = svc.submit(req)
    assert h2.rejected and h2.shard == -1
    assert "outstanding" in h2.reject_reason
    results = svc.run_until_idle()
    assert [r.handle.job_id for r in results] == [h1.job_id]
    # quotas are adjustable at runtime through the service
    svc.set_quota("acme", TenantQuota(max_outstanding=10))
    assert not svc.submit(req).rejected


def test_service_set_quota_needs_elastic_fabric():
    svc = PromptTunerService(SimConfig(max_gpus=4))
    with pytest.raises(ValueError, match="elastic"):
        svc.set_quota("acme", TenantQuota(max_outstanding=1))


# -- golden safety ----------------------------------------------------------------


def test_single_shard_elastic_is_a_noop():
    """shards=1 with the controller attached must be float-for-float
    identical to the plain fabric (the control loop only acts across
    shards)."""
    jobs = generate_trace(TraceConfig(load="low", seed=3, minutes=3))
    ref = ClusterFabric(SimConfig(max_gpus=16), "prompttuner",
                        shards=1).run(clone_jobs(jobs)).summary()
    got = ClusterFabric(SimConfig(max_gpus=16), "prompttuner", shards=1,
                        elastic=True).run(clone_jobs(jobs)).summary()
    assert got == ref


def test_elastic_true_uses_default_config():
    fab = ClusterFabric(SimConfig(max_gpus=8), "prompttuner", shards=2,
                        elastic=True)
    assert fab.controller is not None
    assert fab.controller.cfg.steal_enabled
    assert ClusterFabric(SimConfig(max_gpus=8), "prompttuner",
                         shards=2).controller is None


def test_elastic_beats_static_on_bursty_mix():
    """The tentpole claim, at test scale: on the bursty mix the full
    control plane (steal + autoscale + best-effort cost cap) must cut
    the SLO violation rate AND the billed cost versus the same fleet
    statically placed."""
    jobs = generate_tenant_mix(BURSTY_TENANT_MIX, minutes=5, seed=0)
    static = ClusterFabric(SimConfig(max_gpus=32), "prompttuner",
                           shards=8).run(clone_jobs(jobs)).summary()
    fab = ClusterFabric(
        SimConfig(max_gpus=32), "prompttuner", shards=8,
        elastic=ElasticConfig(
            quotas={"initech": TenantQuota(cost_usd=5.0)}))
    elastic = fab.run(clone_jobs(jobs)).summary()
    assert elastic["slo_violation_pct"] < static["slo_violation_pct"]
    assert elastic["cost_usd"] < static["cost_usd"]
    assert fab.controller.steals > 0
