"""Policy/mechanism split: registry round-trip, exact equivalence with
the pre-split (seed) subclass implementations, ResourceView invariants,
and the new cheap baselines."""
import numpy as np
import pytest

from repro.cluster import (
    ClusterEngine,
    SimConfig,
    TraceConfig,
    WarmPool,
    clone_jobs,
    generate_trace,
    make_system,
    policies,
)
from repro.cluster.baselines import ElasticFlowSim, INFlessSim
from repro.core.scheduler import PromptTunerSim

# SimResult.summary() of the seed ClusterSim subclasses (captured at the
# commit before the policy split) on fixed-seed traces. The refactor is
# required to reproduce these EXACTLY: the engine is pure mechanism and
# the policies are ports, not rewrites.
GOLDEN = {
    ("low", 3, 4, 16): {
        "prompttuner": {
            "jobs": 138, "slo_violation_pct": 57.2463768115942,
            "cost_usd": 19.19311174999994, "gpu_seconds": 13474.0,
            "makespan_s": 1013.0},
        "infless": {
            "jobs": 138, "slo_violation_pct": 94.20289855072464,
            "cost_usd": 22.48224324002943, "gpu_seconds": 15661.5,
            "makespan_s": 982.5},
        "elasticflow": {
            "jobs": 138, "slo_violation_pct": 94.92753623188406,
            "cost_usd": 25.529213192128992, "gpu_seconds": 17936.0,
            "makespan_s": 1121.0},
        "prompttuner-nobank": {
            "jobs": 138, "slo_violation_pct": 76.81159420289855,
            "cost_usd": 41.443141839406394, "gpu_seconds": 29117.0,
            "makespan_s": 2299.5},
        "prompttuner-nodelay": {
            "jobs": 138, "slo_violation_pct": 61.59420289855072,
            "cost_usd": 19.655083939236178, "gpu_seconds": 13784.5,
            "makespan_s": 1102.0},
        "prompttuner-nowarm": {
            "jobs": 138, "slo_violation_pct": 90.57971014492753,
            "cost_usd": 29.529126972626376,
            "gpu_seconds": 20575.113500000003, "makespan_s": 1396.5},
    },
    ("medium", 7, 3, 32): {
        "prompttuner": {
            "jobs": 213, "slo_violation_pct": 39.906103286384976,
            "cost_usd": 24.81654678327542, "gpu_seconds": 17413.5,
            "makespan_s": 763.0},
        "infless": {
            "jobs": 213, "slo_violation_pct": 96.24413145539906,
            "cost_usd": 42.54249353325627, "gpu_seconds": 29778.5,
            "makespan_s": 963.0},
        "elasticflow": {
            "jobs": 213, "slo_violation_pct": 91.54929577464789,
            "cost_usd": 38.03603832899307, "gpu_seconds": 26720.0,
            "makespan_s": 835.0},
    },
}
ABLATION_KW = {
    "nobank": dict(use_bank=False),
    "nodelay": dict(use_delay=False),
    "nowarm": dict(use_warm=False),
}


def _cfg_for(name, gpus):
    if "-" in name:
        base, tag = name.split("-", 1)
        # ablation tags only apply to prompttuner goldens
        if tag in ABLATION_KW:
            return base, SimConfig(max_gpus=gpus, **ABLATION_KW[tag])
    return name, SimConfig(max_gpus=gpus)


@pytest.mark.parametrize("trace_key", sorted(GOLDEN), ids=str)
def test_registry_policies_reproduce_seed_exactly(trace_key):
    load, seed, minutes, gpus = trace_key
    jobs = generate_trace(TraceConfig(load=load, seed=seed, minutes=minutes))
    for sysname, want in GOLDEN[trace_key].items():
        base, cfg = _cfg_for(sysname, gpus)
        got = policies.build(base, cfg).run(clone_jobs(jobs)).summary()
        for metric, v in want.items():
            assert got[metric] == pytest.approx(v, rel=1e-9, abs=1e-9), (
                f"{sysname}/{metric}")


def test_legacy_shims_match_registry():
    """PromptTunerSim / INFlessSim / ElasticFlowSim / make_system are
    one-line wrappers over the registry and agree with it."""
    jobs = generate_trace(TraceConfig(load="low", seed=5, minutes=3))
    for name, shim in [("prompttuner", PromptTunerSim),
                       ("infless", INFlessSim),
                       ("elasticflow", ElasticFlowSim)]:
        via_registry = policies.build(name, SimConfig(max_gpus=16)).run(
            clone_jobs(jobs)).summary()
        via_shim = shim(SimConfig(max_gpus=16)).run(clone_jobs(jobs)).summary()
        via_make = make_system(name, SimConfig(max_gpus=16)).run(
            clone_jobs(jobs)).summary()
        assert via_shim == via_registry == via_make, name


def test_registry_surface():
    for name in ("prompttuner", "infless", "elasticflow", "fifo", "edf-cold"):
        assert name in policies.available()
        cls = policies.get(name)
        assert cls.name == name
        eng = policies.build(name, SimConfig(max_gpus=8))
        assert isinstance(eng, ClusterEngine)
        assert eng.name == name
    with pytest.raises(KeyError, match="unknown policy"):
        policies.get("nope")


def test_engine_is_policy_free():
    """The mechanism layer must contain no system-specific logic: no
    concrete system name may appear in engine.py outside docstrings and
    comments."""
    import ast
    import inspect

    import repro.cluster.engine as engine_mod
    tree = ast.parse(inspect.getsource(engine_mod))
    code_words = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            code_words.add(node.id.lower())
        elif isinstance(node, ast.Attribute):
            code_words.add(node.attr.lower())
        elif isinstance(node, (ast.FunctionDef, ast.ClassDef)):
            code_words.add(node.name.lower())
    for word in ("prompttuner", "infless", "elasticflow", "alg1", "alg2",
                 "delayschedulable"):
        hits = [w for w in code_words if word in w]
        assert not hits, f"engine.py code references {hits}"


def test_new_baselines_run_all_jobs():
    jobs = generate_trace(TraceConfig(load="low", seed=2, minutes=3))
    for name in ("fifo", "edf-cold"):
        res = policies.build(name, SimConfig(max_gpus=32)).run(
            clone_jobs(jobs))
        finished = [r for r in res.records if np.isfinite(r.finish)]
        assert len(finished) == len(jobs), name
        assert res.cost > 0, name


def test_unschedulable_job_fails_fast():
    """A job whose replica unit exceeds the fleet can never be placed by
    ANY policy; the engine must record the violation immediately instead
    of spinning scheduler rounds to the 24 h horizon."""
    from repro.core.jobs import Job

    job = Job(0, "llama-30b", 0.0, 10.0, iters_manual=100, iters_bank=25)
    for name in policies.available():
        res = policies.build(name, SimConfig(max_gpus=2)).run([job])
        assert len(res.records) == 1, name
        assert res.records[0].violated and res.records[0].gpus == 0, name
        assert res.makespan < 60.0, f"{name}: engine spun to the horizon"


def test_slo_aware_policies_beat_fifo():
    """FIFO is the floor: deadline-aware policies should not violate
    more SLOs on a contended trace."""
    jobs = generate_trace(TraceConfig(load="high", seed=4, minutes=5))
    out = {name: policies.build(name, SimConfig(max_gpus=24)).run(
        clone_jobs(jobs)).summary() for name in ("prompttuner", "fifo")}
    assert (out["prompttuner"]["slo_violation_pct"]
            <= out["fifo"]["slo_violation_pct"])


# -- ResourceView / WarmPool invariants ------------------------------------------


def test_view_cold_pool_never_negative():
    eng = ClusterEngine(SimConfig(max_gpus=4))
    view = eng.view
    view.warm_up("gpt2-base", 3, ready_in=1.0)
    assert eng.cold_free == 1
    with pytest.raises(ValueError, match="warm_up"):
        view.warm_up("gpt2-base", 2, ready_in=1.0)
    with pytest.raises(ValueError, match="claim_cold_busy"):
        view.claim_cold_busy("gpt2-base", 2)
    view.claim_cold_busy("gpt2-base", 1)
    assert eng.cold_free == 0
    with pytest.raises(ValueError, match="return_cold"):
        view.return_cold("gpt2-base", 5)


def test_view_warm_accounting_conserved():
    """warm_up -> mature -> take -> release -> reclaim conserves GPUs."""
    cfg = SimConfig(max_gpus=8, reclaim_window=10.0)
    eng = ClusterEngine(cfg)
    view = eng.view
    view.warm_up("gpt2-base", 5, ready_in=2.0)
    pool = view.pool("gpt2-base")
    assert (eng.cold_free, pool.total()) == (3, 5)
    eng.now = 3.0
    reclaimed = view.mature_and_reclaim(cfg.reclaim_window)
    assert reclaimed == 0 and len(pool.idle) == 5
    assert pool.take_idle(4) == 4
    assert (len(pool.idle), pool.busy) == (1, 4)
    view.release("gpt2-base", 4)
    assert (len(pool.idle), pool.busy) == (5, 0)
    eng.now = 30.0                       # all idle GPUs age past the window
    assert view.mature_and_reclaim(cfg.reclaim_window) == 5
    assert eng.cold_free == cfg.max_gpus
    assert pool.total() == 0


def _pool_snapshot(eng, llm):
    p = eng.view.pool(llm)
    return (eng.cold_free, list(p.idle), list(p.warming), p.busy)


def test_warm_up_overdraw_raises_and_leaves_accounting_unchanged():
    eng = ClusterEngine(SimConfig(max_gpus=4))
    eng.view.warm_up("gpt2-base", 2, ready_in=1.0)
    before = _pool_snapshot(eng, "gpt2-base")
    with pytest.raises(ValueError, match="warm_up"):
        eng.view.warm_up("gpt2-base", 3, ready_in=1.0)
    assert _pool_snapshot(eng, "gpt2-base") == before


def test_claim_cold_busy_overdraw_raises_and_leaves_accounting_unchanged():
    eng = ClusterEngine(SimConfig(max_gpus=4))
    eng.view.claim_cold_busy("gpt2-base", 3)
    before = _pool_snapshot(eng, "gpt2-base")
    with pytest.raises(ValueError, match="claim_cold_busy"):
        eng.view.claim_cold_busy("gpt2-base", 2)
    assert _pool_snapshot(eng, "gpt2-base") == before


def test_return_cold_overdraw_raises_and_leaves_accounting_unchanged():
    eng = ClusterEngine(SimConfig(max_gpus=4))
    eng.view.claim_cold_busy("gpt2-base", 2)
    before = _pool_snapshot(eng, "gpt2-base")
    with pytest.raises(ValueError, match="return_cold"):
        eng.view.return_cold("gpt2-base", 3)
    assert _pool_snapshot(eng, "gpt2-base") == before
    # a second LLM's pool has zero busy GPUs: any return overdraws
    with pytest.raises(ValueError, match="return_cold"):
        eng.view.return_cold("vicuna-7b", 1)
    assert _pool_snapshot(eng, "gpt2-base") == before
    assert eng.view.pool("vicuna-7b").total() == 0


def test_warmpool_take_release_roundtrip():
    p = WarmPool()
    p.idle = [0.0, 1.0, 2.0]
    assert p.take_idle(5) == 3           # claims at most what's idle
    assert (len(p.idle), p.busy) == (0, 3)
    p.release(3, now=4.0)
    assert (len(p.idle), p.busy) == (3, 0)
    p.warming = [5.0, 9.0]
    p.mature(6.0)
    assert len(p.idle) == 4 and p.warming == [9.0]
    assert p.total() == 5


def test_release_timeline_uses_scheduled_completions():
    """The E_l timeline must come from the engine's actual JOB_DONE
    events — e.g. under the sequential-connect ablation ('w/o Warm
    Allocator'), where a recomputed estimate drifts from the real
    overhead the job paid."""
    from repro.core.jobs import Job

    cfg = SimConfig(max_gpus=8, use_warm_allocator=False)
    eng = ClusterEngine(cfg)
    view = eng.view
    view.warm_up("gpt2-base", 2, ready_in=0.0)
    view.pool("gpt2-base").mature(0.0)
    job = Job(0, "gpt2-base", 0.0, 1000.0, iters_manual=100, iters_bank=25)
    prof = job.profile()
    view.pool("gpt2-base").take_idle(2)
    overhead = prof.warm_overhead * 2     # sequential connects
    view.start_job(job, 2, overhead, False)
    tl = view.release_timeline("gpt2-base")
    assert tl == [eng._finish_at[0]] * 2
    assert tl[0] == pytest.approx(
        100 * (prof.iter_time_1replica / 2) * (1 + prof.comm_frac) + overhead)
