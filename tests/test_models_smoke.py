"""Per-arch smoke tests (deliverable f): reduced same-family variants run
one forward + one LPT train step on CPU; output shapes + no NaNs. Decode
parity: replaying a short sequence token-by-token through the serve path
must reproduce the full forward's logits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TuneConfig
from repro.configs import ASSIGNED_ARCHS, smoke_config
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models import build_model
from repro.train.optimizer import adam


def _inputs(cfg, B=2, S=16, key=0):
    k = jax.random.key(key)
    batch = {
        "tokens": jax.random.randint(k, (B, S), 3, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.fold_in(k, 1), (B, S), 3,
                                     cfg.vocab_size),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.frontend.kind != "none":
        batch["frontend"] = jax.random.normal(
            jax.random.fold_in(k, 2),
            (B, cfg.frontend.num_embeddings, cfg.frontend.embed_dim),
            jnp.float32,
        )
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _inputs(cfg)
    tc = TuneConfig(prompt_len=4, lr=0.1)
    step, opt = make_train_step(model, tc)
    pp = {"soft_prompt": jnp.zeros((4, cfg.d_model), jnp.float32)}
    opt_state = opt.init(pp)
    pp2, opt_state2, loss = jax.jit(step)(params, pp, opt_state, batch)
    assert jnp.isfinite(loss), arch
    assert pp2["soft_prompt"].shape == (4, cfg.d_model)
    # the step must actually move the prompt
    assert float(jnp.abs(pp2["soft_prompt"] - pp["soft_prompt"]).max()) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_scores(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _inputs(cfg)
    fn = make_prefill_step(model, ce_chunk=8)
    pp = {"soft_prompt": jnp.zeros((4, cfg.d_model), jnp.float32)}
    per_ex = jax.jit(fn)(params, pp, batch)
    assert per_ex.shape == (2,)
    assert bool(jnp.isfinite(per_ex).all()), arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_serve_step_shapes(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    cache = model.init_cache(2, 32)
    fn = make_serve_step(model)
    nxt, cache2 = jax.jit(fn)(params, cache,
                              jnp.full((2, 1), 3, jnp.int32), jnp.int32(0))
    assert nxt.shape == (2, 1) and nxt.dtype == jnp.int32
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ["qwen2-7b", "phi3-medium-14b",
                                  "command-r-plus-104b", "rwkv6-7b",
                                  "deepseek-v2-236b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the full forward logits."""
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 1, 8
    toks = jax.random.randint(jax.random.key(1), (B, S), 3, cfg.vocab_size)
    full_logits, _ = model.forward(params, toks)
    cache = model.init_cache(B, 16)
    outs = []
    for t in range(S):
        logits, cache = model.decode_step(
            params, cache, toks[:, t:t + 1], jnp.int32(t))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_hybrid_decode_matches_forward():
    cfg = smoke_config("zamba2-7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 6), 3, cfg.vocab_size)
    full_logits, _ = model.forward(params, toks)
    cache = model.init_cache(1, 8)
    outs = []
    for t in range(6):
        logits, cache = model.decode_step(
            params, cache, toks[:, t:t + 1], jnp.int32(t))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=5e-3, atol=5e-3)
