"""Roofline extraction: trip-count-aware HLO analysis vs known ground
truth, collective parsing, term math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.extract import (
    HBM_BW,
    LINK_BW,
    PEAK_FLOPS,
    active_params,
    model_flops,
    roofline_terms,
)
from repro.roofline.hlo_analysis import analyze_hlo, parse_hlo


def _hlo_of(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_flops_single_matmul():
    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 256), jnp.float32)
    res = analyze_hlo(_hlo_of(lambda x, y: x @ y, a, b))
    # 2 * 64 * 128 * 256
    assert res["flops"] == pytest.approx(2 * 64 * 128 * 256, rel=0.01)


def test_flops_scan_multiplies_trip_count():
    """THE bug this module exists for: XLA cost_analysis counts a scan
    body once; the analyzer must multiply by the trip count."""
    a = jnp.zeros((64, 64), jnp.float32)

    def fn(x):
        def body(c, _):
            return c @ a, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    res = analyze_hlo(_hlo_of(fn, a))
    one = 2 * 64 * 64 * 64
    assert res["flops"] == pytest.approx(10 * one, rel=0.05)


def test_nested_scan_trip_counts():
    a = jnp.zeros((32, 32), jnp.float32)

    def fn(x):
        def inner(c, _):
            return c @ a, None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None

        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    res = analyze_hlo(_hlo_of(fn, a))
    one = 2 * 32 * 32 * 32
    assert res["flops"] == pytest.approx(12 * one, rel=0.05)


def test_collective_parse_synthetic():
    hlo = """
HloModule test, num_partitions=4

ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add
  %ag = f32[512,256]{1,0} all-gather(%ar), dimensions={0}
  ROOT %out = f32[128,256]{1,0} slice(%ag), slice={[0:128], [0:256]}
}
"""
    res = analyze_hlo(hlo)
    c = res["collectives"]["per_op_bytes"]
    assert c["all-reduce"] == 128 * 256 * 4
    assert c["all-gather"] == 512 * 256 * 4


def test_bytes_slice_counts_window_not_operand():
    big = jnp.zeros((4096, 256), jnp.float32)

    def fn(x):
        return jax.lax.dynamic_slice(x, (0, 0), (16, 256)) * 2.0

    res = analyze_hlo(_hlo_of(fn, big))
    # the 4 MB operand must not be charged for a 16 KB read
    assert res["bytes"] < 1e6


def test_roofline_terms_dominance():
    t = roofline_terms(PEAK_FLOPS, 0.0, 0.0)          # 1 s of pure compute
    assert t["dominant"] == "compute"
    assert t["compute_s"] == pytest.approx(1.0)
    t = roofline_terms(0.0, HBM_BW * 2, 0.0)
    assert t["dominant"] == "memory" and t["memory_s"] == pytest.approx(2.0)
    t = roofline_terms(0.0, 0.0, LINK_BW * 3)
    assert t["dominant"] == "collective"


def test_active_params_dense_vs_moe():
    from repro.configs import get_config
    qwen = get_config("qwen2-7b")
    n = active_params(qwen)
    assert 5.5e9 < n < 8e9                   # ~7B (excl. embeddings)
    kimi = get_config("kimi-k2-1t-a32b")
    n_active = active_params(kimi)
    assert n_active < 60e9                   # a32b: active << total 1T


def test_model_flops_train_vs_inference():
    from repro.config import INPUT_SHAPES
    from repro.configs import get_config
    cfg = get_config("qwen2-7b")
    tr = model_flops(cfg, INPUT_SHAPES["train_4k"], backward=True)
    pf = model_flops(cfg, INPUT_SHAPES["prefill_32k"], backward=False)
    assert tr / 3 == pytest.approx(
        pf, rel=0.01)                        # same token count, 6ND vs 2ND


def test_parse_hlo_computation_count():
    a = jnp.zeros((8, 8), jnp.float32)
    comps = parse_hlo(_hlo_of(lambda x: x @ x, a))
    assert any(c.instrs for c in comps.values())
