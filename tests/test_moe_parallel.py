"""Expert-parallel (shard_map) MoE must be numerically equivalent to the
dense scatter dispatch. Runs in a subprocess with 4 forced host devices
on a (2, 2) (data, model) mesh."""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, MoEConfig
from repro.models import moe as moe_mod

cfg = ModelConfig(
    name="moe-test", arch_type="moe", num_layers=2, d_model=32,
    num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64,
    dtype="float32", param_dtype="float32", remat=False,
    moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=1,
                  d_ff_expert=16, capacity_factor=2.0,
                  first_dense_layers=0),
)
mesh = jax.make_mesh((2, 2), ("data", "model"))
key = jax.random.key(0)
B, S, D = 4, 8, cfg.d_model
x = jax.random.normal(key, (B, S, D), jnp.float32)

from repro.models.common import materialize
specs = moe_mod.moe_params(cfg, model_axis=2, data_axis=2)
params = materialize(specs, jax.random.fold_in(key, 1), "float32")

dense_y, dense_aux = moe_mod._moe_ffn_dense(cfg, params, x)

with mesh:
    def f(params, x):
        return moe_mod._moe_ffn_expert_parallel(cfg, params, x, mesh,
                                                ("data",))
    shd = jax.jit(f)
    ep_y, ep_aux = shd(params, x)

err = float(jnp.abs(dense_y - ep_y).max())
# capacity drops can differ between global and per-shard assignment; with
# capacity_factor=2.0 nothing should drop, so outputs must match exactly
print("MAXERR", err)
assert err < 1e-4, f"expert-parallel != dense: {err}"
print("OK")
"""


def test_expert_parallel_matches_dense():
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    # force the CPU platform: without it jax probes for a TPU PJRT
    # plugin, whose GCP-metadata fetch can stall for minutes in
    # sandboxed CI; --xla_force_host_platform_device_count only acts on
    # the host (CPU) platform anyway
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", SCRIPT], cwd=ROOT, env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "OK" in r.stdout
