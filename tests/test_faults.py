"""Fault plane: injection mechanics, crash-aware recovery, graceful
degradation, and the conservation properties chaos must not break."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.cluster import (
    CHAOS_PROFILES,
    ClusterFabric,
    ElasticConfig,
    FaultEvent,
    FaultPlane,
    HazardConfig,
    JOB_ORPHANED,
    JOB_RETRIED,
    JOB_SHED,
    RecoveryPolicy,
    SHARD_FAILED,
    SHARD_RECOVERED,
    SHARD_SLOWED,
    SHARD_WARNED,
    SimConfig,
    TraceConfig,
    clone_jobs,
    fleet_health,
    generate_trace,
)
from repro.core.jobs import Job, SLO_CLASSES


def mk_job(jid, llm="gpt2-base", submit=0.0, slo=600.0, tenant="t0",
           iters_manual=400, iters_bank=200):
    return Job(job_id=jid, llm=llm, submit_time=submit, slo=slo,
               iters_manual=iters_manual, iters_bank=iters_bank,
               tenant=tenant)


def _home_shard(shards=2, gpus=8):
    """The shard llm-affinity deterministically places gpt2-base on."""
    probe = ClusterFabric(SimConfig(max_gpus=gpus), "prompttuner",
                          shards=shards)
    return probe.submit(mk_job(0))


# -- zero overhead off --------------------------------------------------------


def test_empty_fault_plane_is_float_identical_to_no_plane():
    """A plane with nothing scheduled must not perturb a single float:
    the fault path is pay-for-what-you-use."""
    jobs = generate_trace(TraceConfig(load="low", seed=4, minutes=3))
    base = ClusterFabric(SimConfig(max_gpus=16), "prompttuner", shards=2)
    res_base = base.run(clone_jobs(jobs))
    armed = ClusterFabric(SimConfig(max_gpus=16), "prompttuner", shards=2,
                          faults=FaultPlane())
    res_armed = armed.run(clone_jobs(jobs))
    assert res_armed.summary() == res_base.summary()
    assert [(r.job.job_id, r.start, r.finish, r.gpus)
            for r in res_armed.records] == \
           [(r.job.job_id, r.start, r.finish, r.gpus)
            for r in res_base.records]


def test_checkpointing_off_by_default_keeps_engine_results():
    """checkpoint_interval_s=None (the default) must leave durations
    untouched even through the new start_job code path."""
    jobs = generate_trace(TraceConfig(load="low", seed=4, minutes=3))
    a = ClusterFabric(SimConfig(max_gpus=16), "prompttuner", shards=1)
    b = ClusterFabric(SimConfig(max_gpus=16, checkpoint_interval_s=None),
                      "prompttuner", shards=1)
    assert a.run(clone_jobs(jobs)).summary() == \
        b.run(clone_jobs(jobs)).summary()


# -- crash / retry mechanics --------------------------------------------------


def test_crash_orphans_then_retries_to_completion():
    home = _home_shard()
    faults = FaultPlane([FaultEvent(kind="crash", time=40.0, shard=home,
                                    down_s=30.0)])
    fab = ClusterFabric(SimConfig(max_gpus=8), "prompttuner", shards=2,
                        faults=faults)
    events = []
    fab.on_event(events.append)
    jobs = [mk_job(i, slo=3000.0) for i in range(6)]
    res = fab.run(clone_jobs(jobs))
    kinds = {e.kind for e in events}
    assert {SHARD_FAILED, SHARD_RECOVERED, JOB_ORPHANED, JOB_RETRIED} <= kinds
    assert faults.crashes == 1 and faults.recoveries == 1
    assert faults.retries > 0 and faults.sheds == 0
    # every job still resolves to exactly one finite terminal record
    assert sorted(r.job.job_id for r in res.records) == list(range(6))
    assert all(np.isfinite(r.finish) for r in res.records)
    assert any(r.job.restarts > 0 for r in res.records)
    # capacity fully restored once the downtime elapsed
    assert faults.capacity_lost() == 0
    assert sum(e.cfg.max_gpus for e in fab.shards) == 8


def test_checkpoint_credit_speeds_up_resume():
    """A job crashed at iteration k must resume from its last checkpoint
    (finishing earlier than a restart-from-zero run of the same crash),
    and the credit must never exceed the work actually done."""
    schedule = [FaultEvent(kind="crash", time=1200.0, shard=0, down_s=10.0)]

    def finish_with(ckpt):
        fab = ClusterFabric(
            SimConfig(max_gpus=8, checkpoint_interval_s=ckpt),
            "prompttuner", shards=1,
            faults=FaultPlane(schedule))
        res = fab.run([mk_job(0, slo=100000.0, iters_manual=20000,
                              iters_bank=20000)])
        (rec,) = res.records
        return rec

    slow = finish_with(None)           # restart from zero
    fast = finish_with(30.0)           # resume from last checkpoint
    assert slow.job.restarts == 1 and fast.job.restarts == 1
    assert fast.job.iters_done > 0
    assert fast.finish < slow.finish
    # checkpoint writes are not free: the pre-crash attempt paid for
    # them, so the saving is bounded by the crash time itself
    assert slow.finish - fast.finish < 1200.0


def test_permanent_crash_of_only_shard_sheds_all_jobs():
    """down_s=None: the shard never comes back; with nowhere to retry,
    every outstanding job must be shed as a violated terminal record."""
    faults = FaultPlane([FaultEvent(kind="crash", time=30.0, shard=0,
                                    down_s=None)])
    fab = ClusterFabric(SimConfig(max_gpus=8), "prompttuner", shards=1,
                        faults=faults)
    events = []
    fab.on_event(events.append)
    jobs = [mk_job(i, slo=3000.0, iters_manual=4000, iters_bank=2000)
            for i in range(4)]
    res = fab.run(clone_jobs(jobs))
    assert faults.sheds > 0
    assert JOB_SHED in {e.kind for e in events}
    assert sorted(r.job.job_id for r in res.records) == list(range(4))
    shed = [r for r in res.records if np.isinf(r.finish)]
    assert shed and all(r.violated for r in shed)


def test_retry_budget_exhaustion_sheds_the_job():
    """A shard that keeps flapping under one long job burns the job's
    retry budget; the plane must shed it instead of retrying forever."""
    faults = FaultPlane(
        [FaultEvent(kind="flap", time=30.0, shard=0, cycles=6,
                    period_s=60.0, down_s=2.0)],
        recovery=RecoveryPolicy(max_retries=2, backoff_base_s=1.0))
    fab = ClusterFabric(SimConfig(max_gpus=8), "prompttuner", shards=1,
                        faults=faults)
    res = fab.run([mk_job(0, slo=100000.0, iters_manual=50000,
                          iters_bank=50000)])
    assert faults.sheds == 1
    assert faults.retries_used(0) == 2
    (rec,) = res.records
    assert rec.violated and np.isinf(rec.finish)


# -- checkpoint policy refinements -------------------------------------------


def test_preemption_snapshot_outruns_unannounced_crash():
    """A warned preemption flushes a final snapshot during the lead, so
    the resumed job keeps every completed iteration; an unannounced
    crash at the same kill instant only keeps whole checkpoint blocks."""
    def rec_with(schedule):
        fab = ClusterFabric(
            SimConfig(max_gpus=8, checkpoint_interval_s=30.0),
            "prompttuner", shards=1, faults=FaultPlane(schedule))
        res = fab.run([mk_job(0, slo=100000.0, iters_manual=20000,
                              iters_bank=20000)])
        (rec,) = res.records
        return rec

    crash = rec_with([FaultEvent(kind="crash", time=1200.0, shard=0,
                                 down_s=10.0)])
    warned = rec_with([FaultEvent(kind="preempt", time=1155.0, shard=0,
                                  lead_s=45.0, down_s=10.0)])
    assert crash.job.restarts == 1 and warned.job.restarts == 1
    assert warned.job.iters_done > crash.job.iters_done
    assert warned.finish < crash.finish


def test_min_compute_gate_skips_short_job_checkpoints():
    """With checkpoint_min_compute_s above every job's compute, the
    fault-free schedule must be float-identical to checkpointing off —
    the write tax is only levied where a resume credit could plausibly
    pay it back — and a crashed short job restarts from zero."""
    jobs = generate_trace(TraceConfig(load="low", seed=4, minutes=3))
    plain = ClusterFabric(SimConfig(max_gpus=16), "prompttuner", shards=1)
    gated = ClusterFabric(
        SimConfig(max_gpus=16, checkpoint_interval_s=30.0,
                  checkpoint_min_compute_s=1e9),
        "prompttuner", shards=1)
    assert plain.run(clone_jobs(jobs)).summary() == \
        gated.run(clone_jobs(jobs)).summary()

    faults = FaultPlane([FaultEvent(kind="crash", time=40.0, shard=0,
                                    down_s=5.0)])
    fab = ClusterFabric(
        SimConfig(max_gpus=8, checkpoint_interval_s=30.0,
                  checkpoint_min_compute_s=1e9),
        "prompttuner", shards=1, faults=faults)
    res = fab.run([mk_job(0, slo=3000.0, iters_manual=2000,
                          iters_bank=2000)])
    (rec,) = res.records
    assert rec.job.restarts == 1 and rec.job.iters_done == 0


# -- graceful degradation: running-job shed -----------------------------------


def test_cancel_running_is_terminal_exactly_once():
    """cancel_running frees the GPUs back to the warm pool, lazily
    invalidates the queued JOB_DONE, and leaves the terminal record to
    the caller — so a cancelled job never double-records."""
    fab = ClusterFabric(SimConfig(max_gpus=4), "prompttuner", shards=1)
    eng = fab.shards[0]
    job = mk_job(0, slo=100000.0, iters_manual=4000, iters_bank=4000)
    eng.begin([job])
    while job.job_id not in eng.running and eng.step():
        pass
    assert job.job_id in eng.running
    assert eng.cancel_running(job.job_id, eng.now) is not None
    assert eng.cancel_running(job.job_id, eng.now) is None  # idempotent
    assert len(eng.pool(job.llm).idle) >= 1
    fab.shed_job(job, eng.now, "test shed")
    while eng.step():                  # drains the stale JOB_DONE event
        pass
    recs = fab.records
    assert [r.job.job_id for r in recs] == [0]
    assert recs[0].violated and np.isinf(recs[0].finish)


def test_doomed_running_best_effort_preempted_for_premium():
    """Graceful degradation under capacity loss: best-effort jobs whose
    violation is already certain are cancelled mid-run once premium
    work queues behind them, and every job still resolves to exactly
    one terminal record."""
    home = _home_shard()
    # the crash lands after the doomed best-effort jobs are already
    # running (cold warm-up done), so the cancel path — not the pending
    # shed — is what has to free their GPUs
    faults = FaultPlane([FaultEvent(kind="crash", time=40.0, shard=1 - home,
                                    down_s=None)])
    fab = ClusterFabric(SimConfig(max_gpus=8), "prompttuner", shards=2,
                        elastic=ElasticConfig(), faults=faults)
    events = []
    fab.on_event(events.append)
    be = [Job(job_id=i, llm="gpt2-base", submit_time=0.0, slo=60.0,
              iters_manual=4000, iters_bank=4000, tenant="hog",
              slo_class=SLO_CLASSES["best-effort"]) for i in range(8)]
    prem = [Job(job_id=100 + i, llm="gpt2-base", submit_time=45.0,
                slo=600.0, iters_manual=400, iters_bank=200, tenant="vip",
                slo_class=SLO_CLASSES["premium"]) for i in range(4)]
    res = fab.run(clone_jobs(be + prem))
    shed_details = [e.detail or "" for e in events if e.kind == JOB_SHED]
    assert any("running" in d for d in shed_details)
    ids = sorted(r.job.job_id for r in res.records)
    assert ids == sorted(j.job_id for j in be + prem)
    assert all(np.isfinite(r.finish) for r in res.records
               if r.job.job_id >= 100)


# -- preemption warning / drain ----------------------------------------------


def test_preemption_warning_drains_pending_to_healthy_shard():
    home = _home_shard()
    faults = FaultPlane([FaultEvent(kind="preempt", time=20.0, shard=home,
                                    lead_s=60.0, down_s=120.0)])
    fab = ClusterFabric(SimConfig(max_gpus=8), "prompttuner", shards=2,
                        elastic=ElasticConfig(), faults=faults)
    events = []
    fab.on_event(events.append)
    jobs = [mk_job(i, slo=4000.0) for i in range(10)]
    res = fab.run(clone_jobs(jobs))
    kinds = {e.kind for e in events}
    assert SHARD_WARNED in kinds and SHARD_FAILED in kinds
    assert faults.preemptions == 1 and faults.warnings == 1
    # the controller moved queued work off the doomed shard in the
    # warning window (drains don't spend the per-cycle steal budget)
    assert fab.controller.drains > 0
    assert sorted(r.job.job_id for r in res.records) == list(range(10))
    assert all(np.isfinite(r.finish) for r in res.records)


def test_warned_shard_stops_attracting_placement():
    home = _home_shard()
    faults = FaultPlane([FaultEvent(kind="preempt", time=0.0, shard=home,
                                    lead_s=300.0, down_s=60.0)])
    fab = ClusterFabric(SimConfig(max_gpus=8), "prompttuner", shards=2,
                        faults=faults)
    faults.fire_next()                 # the warn action at t=0
    assert home in faults.warned
    assert not fab.shard_admissible(home)
    assert fab.submit(mk_job(99)) != home


# -- slowdown ----------------------------------------------------------------


def test_slowdown_stretches_execution():
    def finish_with(schedule):
        fab = ClusterFabric(SimConfig(max_gpus=8), "prompttuner", shards=1,
                            faults=FaultPlane(schedule))
        events = []
        fab.on_event(events.append)
        res = fab.run([mk_job(0, slo=100000.0)])
        (rec,) = res.records
        return rec, events

    base, _ = finish_with([])
    slowed, events = finish_with(
        [FaultEvent(kind="slow", time=0.0, shard=0, factor=3.0,
                    duration_s=1e6)])
    assert SHARD_SLOWED in {e.kind for e in events}
    assert slowed.finish > base.finish
    # a 3x straggler should stretch compute by ~3x, not just jitter it
    assert slowed.finish > base.finish * 1.5


# -- flap quarantine ----------------------------------------------------------


def test_flapping_shard_is_quarantined():
    home = _home_shard()
    faults = FaultPlane([FaultEvent(kind="flap", time=20.0, shard=home,
                                    cycles=3, period_s=40.0, down_s=5.0)])
    fab = ClusterFabric(
        SimConfig(max_gpus=8), "prompttuner", shards=2,
        elastic=ElasticConfig(flap_threshold=2, flap_window=600.0,
                              quarantine_s=300.0),
        faults=faults)
    jobs = [mk_job(i, slo=6000.0) for i in range(8)]
    res = fab.run(clone_jobs(jobs))
    assert faults.crashes == 3
    assert fab.controller.quarantines >= 1
    assert sorted(r.job.job_id for r in res.records) == list(range(8))


def test_health_snapshot_carries_failure_signals():
    faults = FaultPlane([FaultEvent(kind="crash", time=10.0, shard=0,
                                    down_s=1e6)])
    fab = ClusterFabric(SimConfig(max_gpus=8), "prompttuner", shards=2,
                        faults=faults)
    faults.fire_next()                 # the crash at t=10
    healths = fleet_health(fab.shards, faults)
    assert not healths[0].alive and healths[0].recent_failures == 1
    assert healths[1].alive and healths[1].recent_failures == 0


# -- conservation properties under random chaos -------------------------------


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), shards=st.sampled_from([1, 2, 4]),
       elastic=st.sampled_from([True, False]))
@pytest.mark.slow
def test_chaos_conserves_replicas_and_terminal_records(seed, shards,
                                                       elastic):
    """Across random fault schedules x shard counts x elastic on/off:
    (1) fleet replica conservation — live capacity plus capacity lost
    to down shards always equals the provisioned fleet; (2) every
    submitted job resolves to exactly one terminal record."""
    jobs = generate_trace(TraceConfig(load="low", seed=seed % 5, minutes=3))
    hz = HazardConfig(crash_rate=30.0, preempt_rate=15.0, slow_rate=15.0,
                      flap_rate=8.0, mean_downtime_s=45.0,
                      preempt_lead_s=20.0, flap_period_s=30.0,
                      horizon_s=400.0)
    faults = FaultPlane(hazard=hz, seed=seed)
    fab = ClusterFabric(
        SimConfig(max_gpus=16, checkpoint_interval_s=20.0), "prompttuner",
        shards=shards, elastic=ElasticConfig() if elastic else None,
        faults=faults)

    def check_conservation(ev):
        if ev.kind in ("round", "job_done"):
            assert (sum(e.cfg.max_gpus for e in fab.shards)
                    + faults.capacity_lost()) == 16

    fab.on_event(check_conservation)
    res = fab.run(clone_jobs(jobs))

    assert (sum(e.cfg.max_gpus for e in fab.shards)
            + faults.capacity_lost()) == 16
    ids = sorted(r.job.job_id for r in res.records)
    assert ids == sorted(j.job_id for j in jobs), (
        "terminal records must be exactly one per submitted job")
    # terminal kinds partition cleanly: finite finish or violated shed
    for r in res.records:
        assert np.isfinite(r.finish) or r.violated


def test_chaos_profiles_are_reproducible():
    """Same seed + profile => the identical fault history, run to run."""
    jobs = generate_trace(TraceConfig(load="low", seed=1, minutes=3))

    def history(seed):
        faults = FaultPlane(hazard=CHAOS_PROFILES["mixed"], seed=seed)
        fab = ClusterFabric(SimConfig(max_gpus=16), "prompttuner",
                            shards=2, faults=faults)
        events = []
        fab.on_event(events.append)
        fab.run(clone_jobs(jobs))
        return ([(e.time, e.kind, e.shard) for e in events
                 if e.kind.startswith("shard_")],
                (faults.crashes, faults.preemptions, faults.slowdowns))

    assert history(7) == history(7)
    assert history(7) != history(8)
