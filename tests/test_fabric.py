"""ClusterFabric: 1-shard golden equivalence, shard placement, the
streaming event API, and the multi-tenant ledgers / SLO classes."""
import numpy as np
import pytest

from repro.cluster import (
    ClusterEngine,
    ClusterFabric,
    SHARED_POOL,
    SimConfig,
    TenantSpec,
    TraceConfig,
    clone_jobs,
    generate_tenant_mix,
    generate_trace,
    placements,
    policies,
)
from repro.cluster.engine import ARRIVAL, JOB_DONE, ROUND
from repro.core.jobs import DEFAULT_SLO_CLASS, SLO_CLASSES, Job, SLOClass

from test_policies import GOLDEN, _cfg_for


# -- golden equivalence -----------------------------------------------------------


@pytest.mark.parametrize("trace_key", sorted(GOLDEN), ids=str)
def test_one_shard_fabric_reproduces_goldens_exactly(trace_key):
    """ClusterFabric(shards=1) with the default single tenant must be
    float-for-float identical to the bare engine for every pinned
    policy golden."""
    load, seed, minutes, gpus = trace_key
    jobs = generate_trace(TraceConfig(load=load, seed=seed, minutes=minutes))
    for sysname, want in GOLDEN[trace_key].items():
        base, cfg = _cfg_for(sysname, gpus)
        fab = ClusterFabric(cfg, base, shards=1)
        got = fab.run(clone_jobs(jobs)).summary()
        for metric, v in want.items():
            assert got[metric] == pytest.approx(v, rel=1e-9, abs=1e-9), (
                f"{sysname}/{metric}")


def test_one_shard_stream_one_job_done_per_completion():
    jobs = generate_trace(TraceConfig(load="low", seed=3, minutes=3))
    fab = ClusterFabric(SimConfig(max_gpus=16), "prompttuner", shards=1)
    events = []
    fab.on_event(events.append)
    res = fab.run(clone_jobs(jobs))
    completed = [r for r in res.records if np.isfinite(r.finish)]
    done = [e for e in events if e.kind == JOB_DONE]
    assert len(done) == len(completed)
    assert sorted(e.job.job_id for e in done) == sorted(
        r.job.job_id for r in completed)
    arrivals = [e for e in events if e.kind == ARRIVAL]
    assert len(arrivals) == len(jobs)
    assert all(e.shard == 0 for e in events)
    assert any(e.kind == ROUND for e in events)


# -- sharding ---------------------------------------------------------------------


def test_fabric_splits_fleet_and_conserves_jobs():
    jobs = generate_trace(TraceConfig(load="low", seed=1, minutes=3))
    for shards in (2, 3, 4):
        fab = ClusterFabric(SimConfig(max_gpus=32), "prompttuner",
                            shards=shards)
        assert len(fab.shards) == shards
        assert sum(e.cfg.max_gpus for e in fab.shards) == 32
        res = fab.run(clone_jobs(jobs))
        assert len(res.records) == len(jobs)
        assert res.cost == pytest.approx(
            sum(e.cost for e in fab.shards))
        assert res.makespan == max(e.now for e in fab.shards)


def test_fabric_stream_is_globally_time_ordered():
    jobs = generate_trace(TraceConfig(load="low", seed=2, minutes=3))
    fab = ClusterFabric(SimConfig(max_gpus=24), "prompttuner", shards=3)
    events = []
    fab.on_event(events.append)
    res = fab.run(clone_jobs(jobs))
    times = [e.time for e in events]
    assert times == sorted(times)
    assert {e.shard for e in events} <= {0, 1, 2}
    done = [e for e in events if e.kind == JOB_DONE]
    completed = [r for r in res.records if np.isfinite(r.finish)]
    assert len(done) == len(completed)


def test_placement_registry_and_llm_affinity():
    assert {"llm-affinity", "least-loaded", "hash"} <= set(placements())
    fab = ClusterFabric(SimConfig(max_gpus=8), "fifo", shards=4)
    jobs = generate_trace(TraceConfig(load="low", seed=0, minutes=2))
    by_llm = {}
    for j in jobs:
        shard = fab.submit(j)
        assert fab.placed[j.job_id] == shard
        by_llm.setdefault(j.llm, set()).add(shard)
    # llm-affinity: one shard per LLM, reproducibly
    assert all(len(s) == 1 for s in by_llm.values())
    with pytest.raises(KeyError, match="unknown placement"):
        ClusterFabric(SimConfig(max_gpus=8), "fifo", shards=2,
                      placement="nope")
    with pytest.raises(ValueError, match="shards"):
        ClusterFabric(SimConfig(max_gpus=8), "fifo", shards=0)
    with pytest.raises(ValueError, match="split"):
        ClusterFabric(SimConfig(max_gpus=2), "fifo", shards=4)


def test_register_placement_round_trip():
    """Custom placements registered after import are listed, usable by
    name, and actually consulted by the fabric."""
    from repro.cluster.fabric import _PLACEMENTS, register_placement

    calls = []

    @register_placement("always-last")
    def _always_last(job, shards):
        calls.append(job.job_id)
        return len(shards) - 1

    try:
        assert "always-last" in placements()
        fab = ClusterFabric(SimConfig(max_gpus=8), "fifo", shards=4,
                            placement="always-last")
        jobs = generate_trace(TraceConfig(load="low", seed=0, minutes=1))
        for j in jobs:
            assert fab.submit(j) == 3
        assert calls == [j.job_id for j in jobs]
    finally:
        del _PLACEMENTS["always-last"]
    with pytest.raises(KeyError, match="unknown placement"):
        ClusterFabric(SimConfig(max_gpus=8), "fifo", shards=2,
                      placement="always-last")


def test_least_loaded_spreads_and_hash_is_stable():
    jobs = generate_trace(TraceConfig(load="medium", seed=5, minutes=3))
    fab = ClusterFabric(SimConfig(max_gpus=32), "prompttuner", shards=4,
                        placement="least-loaded")
    used = {fab.submit(j) for j in clone_jobs(jobs)}
    assert used == {0, 1, 2, 3}
    placed = {}
    for _ in range(2):
        fab2 = ClusterFabric(SimConfig(max_gpus=32), "prompttuner",
                             shards=4, placement="hash")
        got = {j.job_id: fab2.submit(j) for j in clone_jobs(jobs)}
        placed.setdefault("runs", []).append(got)
    assert placed["runs"][0] == placed["runs"][1]   # crc32, not salted hash


def test_placement_respects_shard_capacity():
    """A job whose replica unit fits some shard must never be stranded
    on a too-small one by the hash/affinity placement (uneven splits
    fragment the fleet); only when NO shard can hold one replica is the
    fabric-level violation legitimate."""
    def mk():
        return Job(job_id=0, llm="llama-30b", submit_time=0.0, slo=4000.0,
                   iters_manual=50, iters_bank=20)

    # 10 GPUs over 3 shards -> 4/3/3: only shard 0 fits a 4-GPU replica
    for placement in placements():
        fab = ClusterFabric(SimConfig(max_gpus=10), "prompttuner",
                            shards=3, placement=placement)
        assert fab.submit(mk()) == 0, placement
        res = fab.run()
        assert len(res.records) == 1
        assert np.isfinite(res.records[0].finish), placement
    # 8 GPUs over 4 shards -> 2 each: genuinely unschedulable anywhere
    fab = ClusterFabric(SimConfig(max_gpus=8), "prompttuner", shards=4)
    fab.submit(mk())
    res = fab.run()
    assert res.records[0].violated and res.records[0].gpus == 0


def test_on_event_subscribe_after_construction_and_repeated_run():
    """on_event must accept subscribers any time before run(), and a
    second run() must not re-register shard forwarders (each event is
    delivered exactly once, ever)."""
    fab = ClusterFabric(SimConfig(max_gpus=16), "prompttuner", shards=2)
    first = generate_trace(TraceConfig(load="low", seed=7, minutes=2))
    events = []
    fab.on_event(events.append)          # after construction, before run
    fab.run(clone_jobs(first))
    done1 = [e for e in events if e.kind == JOB_DONE]
    assert len(done1) == len(first)

    # subscribe a second callback between runs; resubmit fresh jobs
    late_events = []
    fab.on_event(late_events.append)
    second = clone_jobs(first)
    for j in second:
        j.job_id += 10_000
        j.submit_time += fab.now
    fab.run(second)
    done2 = [e for e in events if e.kind == JOB_DONE]
    # exactly one JOB_DONE per job across both runs — double-registered
    # forwarders would duplicate every second-run event
    assert len(done2) == len(first) + len(second)
    assert len([e for e in late_events if e.kind == JOB_DONE]) == len(second)
    done_ids = [e.job.job_id for e in done2]
    assert len(done_ids) == len(set(done_ids))


# -- incremental step API ---------------------------------------------------------


def test_engine_step_loop_matches_run():
    jobs = generate_trace(TraceConfig(load="low", seed=9, minutes=2))
    ref = policies.build("prompttuner", SimConfig(max_gpus=16)).run(
        clone_jobs(jobs)).summary()
    eng = policies.build("prompttuner", SimConfig(max_gpus=16))
    eng.begin(clone_jobs(jobs))
    steps = 0
    while eng.step():
        steps += 1
    got = eng.finish().summary()
    assert got == ref
    assert steps > len(jobs)            # arrivals + rounds + completions
    assert eng.next_event_time() is None and not eng.has_events()


# -- multi-tenant ledgers / SLO classes -------------------------------------------


def test_tenant_mix_stamps_and_ledgers():
    mix = generate_tenant_mix(minutes=3, seed=4)
    tenants = {j.tenant for j in mix}
    assert tenants == {"acme", "globex", "initech"}
    assert {j.slo_class.name for j in mix} == {
        "premium", "standard", "best-effort"}
    assert [j.job_id for j in mix] == list(range(len(mix)))
    fab = ClusterFabric(SimConfig(max_gpus=32), "prompttuner", shards=2)
    res = fab.run(clone_jobs(mix))
    by_tenant = res.summary_by_tenant()
    for t in tenants:
        assert by_tenant[t]["jobs"] > 0
        assert by_tenant[t]["gpu_seconds"] > 0
    assert sum(v["jobs"] for v in by_tenant.values()) == len(mix)
    # gpu-second attribution is conservative: busy shares + shared pool
    # add up to the global ledger
    assert sum(res.gpu_seconds_by_tenant.values()) == pytest.approx(
        res.gpu_seconds)
    # premium bills at 2x tier, best-effort at 0.5x: acme's $/GPU-s rate
    # must be strictly higher than initech's
    rate = {t: res.cost_by_tenant[t] / res.gpu_seconds_by_tenant[t]
            for t in tenants}
    assert rate["acme"] > rate["globex"] > rate["initech"]


def test_clone_jobs_preserves_tenancy():
    mix = generate_tenant_mix(minutes=2, seed=0)
    clones = clone_jobs(mix)
    for a, b in zip(mix, clones):
        assert (a.tenant, a.slo_class) == (b.tenant, b.slo_class)
        assert b.slo_class is a.slo_class


def test_slo_class_multiplier_applied_to_trace():
    base = generate_trace(TraceConfig(load="low", seed=6, minutes=2))
    prem = generate_trace(TraceConfig(
        load="low", seed=6, minutes=2, slo_class=SLO_CLASSES["premium"]))
    assert len(base) == len(prem)
    for b, p in zip(base, prem):
        assert p.slo == pytest.approx(b.slo * 0.75)
    assert all(j.slo_class is DEFAULT_SLO_CLASS for j in base)


def test_class_priority_orders_admission():
    """Two service classes with identical SLO stringency on a starved
    fleet: the higher-priority class's jobs must start first even though
    pure EDF would admit the low-priority ones (earlier deadlines)."""
    hi = SLOClass("gold", slo_multiplier=1.0, price_tier=1.0, priority=5)
    lo = DEFAULT_SLO_CLASS

    def mk(jid, cls, slo):
        return Job(job_id=jid, llm="gpt2-base", submit_time=0.0, slo=slo,
                   iters_manual=100, iters_bank=50, tenant=cls.name,
                   slo_class=cls)

    # low-priority jobs have slightly EARLIER deadlines
    jobs = [mk(0, lo, 390.0), mk(1, lo, 395.0),
            mk(2, hi, 400.0), mk(3, hi, 405.0)]
    eng = policies.build("prompttuner", SimConfig(max_gpus=2))
    res = eng.run(jobs)
    start = {r.job.job_id: r.start for r in res.records}
    assert max(start[2], start[3]) < min(start[0], start[1])


def test_single_class_priority_is_noop():
    """With one class everywhere, the class-aware admission key must be
    byte-identical to pure EDF (the goldens already enforce this; this
    is the targeted unit check)."""
    from repro.cluster.policies.base import admission_key
    jobs = generate_trace(TraceConfig(load="low", seed=0, minutes=2))
    assert (sorted(jobs, key=admission_key)
            == sorted(jobs, key=lambda j: j.deadline))


def test_shared_pool_row_absorbs_idle_billing():
    """Serverless-style policies bill idle warm capacity; that slice
    must land on the shared-pool ledger row, not on any tenant."""
    mix = generate_tenant_mix(minutes=2, seed=2)
    fab = ClusterFabric(SimConfig(max_gpus=16), "prompttuner", shards=1)
    res = fab.run(clone_jobs(mix))
    assert res.gpu_seconds_by_tenant.get(SHARED_POOL, 0.0) > 0.0
    busy = sum(v for t, v in res.gpu_seconds_by_tenant.items()
               if t != SHARED_POOL)
    assert busy + res.gpu_seconds_by_tenant[SHARED_POOL] == pytest.approx(
        res.gpu_seconds)


def test_out_of_range_placement_raises_value_error():
    """A buggy placement returning an out-of-range shard index must be
    a clear ValueError naming the culprit, not a downstream IndexError."""
    from repro.cluster.fabric import _PLACEMENTS, register_placement

    @register_placement("off-the-end")
    def _off_the_end(job, shards):
        return len(shards)

    try:
        fab = ClusterFabric(SimConfig(max_gpus=8), "fifo", shards=2,
                            placement="off-the-end")
        job = Job(job_id=0, llm="gpt2-base", submit_time=0.0, slo=600.0,
                  iters_manual=100, iters_bank=50)
        with pytest.raises(ValueError, match=r"'off-the-end' returned "
                                             r"shard index 2.*0\.\.1"):
            fab.submit(job)
    finally:
        del _PLACEMENTS["off-the-end"]


def test_negative_resize_raises_value_error():
    """engine.resize(-k) is a caller bug, rejected loudly — and the
    fabric passes the target through instead of clamping it silently."""
    eng = policies.build("prompttuner", SimConfig(max_gpus=8))
    with pytest.raises(ValueError, match=">= 0 GPUs, got -1"):
        eng.resize(-1)
    assert eng.cfg.max_gpus == 8                    # state untouched
    fab = ClusterFabric(SimConfig(max_gpus=8), "prompttuner", shards=2)
    with pytest.raises(ValueError, match=">= 0 GPUs, got -3"):
        fab.resize_shard(0, -3)
    assert fab.shards[0].cfg.max_gpus == 4


from _hypothesis_compat import given, settings, st  # noqa: E402


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       shards=st.integers(min_value=1, max_value=4),
       elastic_on=st.sampled_from([False, True]))
def test_stream_property_ordered_and_one_done_per_job(seed, shards,
                                                      elastic_on):
    """Property: for any seed / shard count / elastic toggle, the fabric
    event stream is non-decreasing in sim time and every completed job
    gets exactly one JOB_DONE — even when elastic steals rehome jobs."""
    from repro.cluster import ElasticConfig
    jobs = generate_trace(TraceConfig(load="low", seed=seed, minutes=2))
    fab = ClusterFabric(SimConfig(max_gpus=16), "prompttuner",
                        shards=shards,
                        elastic=ElasticConfig() if elastic_on else None)
    events = []
    fab.on_event(events.append)
    res = fab.run(clone_jobs(jobs))
    times = [e.time for e in events]
    assert times == sorted(times)
    done_ids = [e.job.job_id for e in events if e.kind == JOB_DONE]
    assert len(done_ids) == len(set(done_ids))
    completed = sorted(r.job.job_id for r in res.records
                       if np.isfinite(r.finish))
    assert sorted(done_ids) == completed


def test_event_kinds_are_closed_set():
    """WARM_READY is gone: the engine emits exactly the three documented
    event kinds."""
    import repro.cluster.engine as engine_mod
    import repro.cluster.sim as sim_mod

    assert not hasattr(engine_mod, "WARM_READY")
    assert not hasattr(sim_mod, "WARM_READY")
    jobs = generate_trace(TraceConfig(load="low", seed=1, minutes=2))
    fab = ClusterFabric(SimConfig(max_gpus=16), "prompttuner", shards=1)
    kinds = set()
    fab.on_event(lambda e: kinds.add(e.kind))
    fab.run(clone_jobs(jobs))
    assert kinds == {ARRIVAL, ROUND, JOB_DONE}
