"""Degraded fallback when `hypothesis` is not installed.

Tier-1 must collect and run with the baked-in toolchain only. When the
real library is present we re-export it untouched; otherwise `@given`
runs each property test over a small deterministic sample drawn from
lightweight stand-ins for the three strategies this suite uses
(`integers`, `floats`, `sampled_from`). That keeps the properties
exercised (shrinking and edge-case search are lost, which is acceptable
for a fallback) instead of ERRORing the whole module at collection.
"""
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    _FALLBACK_EXAMPLES = 10       # cap: the fallback is breadth, not depth

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng):
            return self._sample(rng)

    class st:                     # noqa: N801 — mimics `strategies` module
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

    def settings(max_examples=_FALLBACK_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def runner(*args, **kwargs):
                rng = random.Random(0)
                n = min(
                    getattr(runner, "_max_examples", None)
                    or getattr(fn, "_max_examples", _FALLBACK_EXAMPLES),
                    _FALLBACK_EXAMPLES,
                )
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)

            # hide the drawn parameters from pytest's fixture resolution
            del runner.__wrapped__
            params = [p for name, p in
                      inspect.signature(fn).parameters.items()
                      if name not in strategies]
            runner.__signature__ = inspect.Signature(params)
            return runner

        return deco
