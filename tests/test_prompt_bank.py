"""Prompt Bank (§4.3): two-layer structure invariants + behaviour."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.prompt_bank import (
    PromptBank,
    PromptEntry,
    cosine_distance,
    k_medoids,
)


def _mk_bank(n=60, d=8, k=6, seed=0, capacity=3000):
    rng = np.random.default_rng(seed)
    # clustered features: `k` gaussian blobs
    centers = rng.normal(size=(k, d))
    entries = []
    for i in range(n):
        c = i % k
        f = centers[c] + rng.normal(scale=0.05, size=d)
        entries.append(PromptEntry(prompt=rng.normal(size=(4, 4)).astype(
            np.float32), feature=f.astype(np.float32), origin=f"blob{c}/{i}"))
    bank = PromptBank(capacity=capacity, num_clusters=k, seed=seed)
    bank.add_candidates(entries)
    bank.build()
    return bank, centers


def test_kmedoids_partitions_blobs():
    bank, centers = _mk_bank()
    # each cluster should be blob-pure (blobs are well separated)
    for ci, members in enumerate(bank.clusters):
        origins = {bank.entries[i].origin.split("/")[0] for i in members}
        assert len(origins) == 1, f"cluster {ci} mixes blobs: {origins}"


def test_kmedoids_medoid_is_member():
    feats = np.random.default_rng(1).normal(size=(40, 6))
    medoids, assign = k_medoids(feats, 5, seed=1)
    assert len(set(medoids.tolist())) == 5
    assert assign.shape == (40,)
    for ci, m in enumerate(medoids):
        assert assign[m] == ci          # a medoid belongs to its own cluster


def test_lookup_matches_flat_when_scores_align_with_features():
    """When the score function is smooth in feature space, the two-layer
    lookup finds (near) the flat-search optimum with ~K + C/K evals."""
    bank, centers = _mk_bank(n=80, k=8)
    target = centers[3]

    def score(e):
        return float(np.linalg.norm(e.feature - target))

    two = bank.lookup(score)
    flat = bank.lookup_flat(score)
    assert two.evaluations < flat.evaluations / 2
    assert two.score <= flat.score * 1.05
    assert two.entry.origin.split("/")[0] == "blob3"


def test_lookup_evaluation_count():
    bank, _ = _mk_bank(n=60, k=6)
    res = bank.lookup(lambda e: float(e.feature[0]))
    best_ci = res.cluster
    expected = len(bank.medoid_ids) + len(bank.clusters[best_ci]) - 1
    assert res.evaluations == expected


def test_insert_routes_to_nearest_cluster_without_scoring():
    bank, centers = _mk_bank()
    new = PromptEntry(prompt=np.zeros((4, 4), np.float32),
                      feature=(centers[2] + 0.01).astype(np.float32),
                      origin="new")
    ci, evicted = bank.insert(new)
    members = {bank.entries[i].origin.split("/")[0]
               for i in bank.clusters[ci] if bank.entries[i].origin != "new"}
    assert members == {"blob2"}
    assert evicted is None              # capacity not exceeded


def test_replacement_evicts_least_diverse():
    bank, centers = _mk_bank(n=30, k=3, capacity=30)
    mid = bank.medoid_ids[0]
    mfeat = bank.entries[mid].feature
    new = PromptEntry(prompt=np.zeros((4, 4), np.float32),
                      feature=mfeat + 1e-4, origin="dup")
    ci, evicted = bank.insert(new)
    assert evicted is not None and evicted != mid
    assert bank.entries[evicted].origin == "<evicted>"
    assert len(bank) == 30              # capacity preserved
    # the evicted entry is never returned by lookup
    res = bank.lookup(lambda e: 0.0)
    assert res.entry.origin != "<evicted>"


def test_expected_evaluations_optimum():
    bank, _ = _mk_bank(n=100, k=10)
    assert bank.expected_evaluations() == pytest.approx(10 + 100 / 10)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(5, 50), k=st.integers(2, 8), seed=st.integers(0, 999))
def test_kmedoids_properties(n, k, seed):
    """Property: every point is assigned to exactly one cluster led by a
    valid medoid index; clusters partition [0, n)."""
    feats = np.random.default_rng(seed).normal(size=(n, 5))
    medoids, assign = k_medoids(feats, k, seed=seed)
    kk = min(k, n)
    assert len(medoids) == kk
    assert ((assign >= 0) & (assign < kk)).all()
    assert sorted(np.unique(medoids).tolist()) == sorted(medoids.tolist())


def test_cosine_distance_range():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(10, 4))
    d = cosine_distance(a, a)
    assert np.allclose(np.diag(d), 0, atol=1e-6)
    assert (d >= -1e-6).all() and (d <= 2 + 1e-6).all()
