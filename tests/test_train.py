"""Training substrate: optimizers, schedules, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import adam, apply_updates, cosine_schedule, sgd
from repro.train.checkpoint import (
    checkpoint_exists,
    load_checkpoint,
    save_checkpoint,
)


def test_adam_minimizes_quadratic():
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = adam(0.1)
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        u, state = opt.update(g, state, params)
        return apply_updates(params, u), state

    for _ in range(200):
        params, state = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_sgd_momentum_runs():
    params = {"w": jnp.ones(4)}
    opt = sgd(0.1, momentum=0.9)
    state = opt.init(params)
    g = {"w": jnp.ones(4)}
    p2, state = opt.update(g, state, params)
    assert p2["w"].shape == (4,)


def test_cosine_schedule_shape():
    fn = cosine_schedule(1.0, warmup=10, total=110)
    assert float(fn(jnp.asarray(0))) == 0.0
    assert float(fn(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(fn(jnp.asarray(60))) == pytest.approx(0.5, abs=0.05)
    assert float(fn(jnp.asarray(110))) == pytest.approx(0.0, abs=1e-6)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "c": jnp.ones(4, jnp.int32)}
    path = os.path.join(tmp_path, "ck.npz")
    save_checkpoint(path, tree, step=7, meta={"x": 1})
    assert checkpoint_exists(path)
    tree2, manifest = load_checkpoint(path)
    assert manifest["step"] == 7 and manifest["meta"]["x"] == 1
    np.testing.assert_array_equal(np.asarray(tree["a"]["b"]),
                                  np.asarray(tree2["a"]["b"]))
    np.testing.assert_array_equal(np.asarray(tree["c"]),
                                  np.asarray(tree2["c"]))


def test_adam_weight_decay_shrinks_params():
    params = {"w": jnp.ones(3) * 10}
    opt = adam(0.01, weight_decay=0.1)
    state = opt.init(params)
    zero_g = {"w": jnp.zeros(3)}
    p2, _ = opt.update(zero_g, state, params)
    p2 = apply_updates(params, p2)
    assert float(p2["w"][0]) < 10.0
