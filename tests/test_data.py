"""Synthetic task families + loader: layout, determinism, invariants."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data import (
    FAMILIES,
    LoaderConfig,
    TaskLoader,
    TaskSpec,
    make_tasks,
    sample_batch,
    task_similarity,
)
from repro.data.synthetic import BOS, N_SPECIAL, SEP, _apply_family


def test_twelve_families_ten_partitions():
    tasks = make_tasks(partitions=10)
    assert len(tasks) == 120              # the paper's 12 datasets x 10
    assert len({t.family for t in tasks}) == 12


@pytest.mark.parametrize("family", FAMILIES)
def test_families_are_deterministic_per_token_maps(family):
    rng = np.random.default_rng(0)
    x = rng.integers(0, 32, size=(4, 8))
    y1 = _apply_family(family, 2, x, 32)
    y2 = _apply_family(family, 2, x, 32)
    assert (y1 == y2).all()
    assert y1.shape == x.shape
    assert ((y1 >= 0) & (y1 < 32)).all()


@pytest.mark.parametrize("family", FAMILIES)
def test_families_differ_across_params(family):
    rng = np.random.default_rng(1)
    x = rng.integers(0, 32, size=(8, 8))
    y0 = _apply_family(family, 0, x, 32)
    y1 = _apply_family(family, 1, x, 32)
    assert (y0 != y1).any(), f"{family}: params 0 and 1 give identical tasks"


def test_batch_layout():
    spec = TaskSpec("shift", 1, 32, input_len=8, target_len=8)
    b = sample_batch(spec, np.random.default_rng(0), 4)
    T = 1 + 8 + 1 + 8 - 1                # BOS x SEP y, minus last shift
    assert b["tokens"].shape == (4, T)
    assert b["tokens"][0, 0] == BOS
    assert b["tokens"][0, 9] == SEP
    # mask covers exactly the target region
    assert b["mask"].sum() == 4 * 8
    assert (b["mask"][:, :9] == 0).all()
    # labels are tokens shifted by one
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
    # data tokens sit above the specials
    assert (b["tokens"][:, 1:9] >= N_SPECIAL).all()


def test_loader_determinism_and_eval_fixture():
    spec = TaskSpec("xor", 3, 32)
    l1 = TaskLoader(spec, LoaderConfig(batch_size=4, seed=7))
    l2 = TaskLoader(spec, LoaderConfig(batch_size=4, seed=7))
    b1, b2 = next(l1), next(l2)
    assert (b1["tokens"] == b2["tokens"]).all()
    e1 = l1.eval_batch(16)
    e2 = l2.eval_batch(16)
    assert (e1["tokens"] == e2["tokens"]).all()   # fixed D_eval


def test_host_sharded_loader_partitions_batch():
    spec = TaskSpec("shift", 1, 32)
    full = TaskLoader(spec, LoaderConfig(batch_size=8, seed=3))
    h0 = TaskLoader(spec, LoaderConfig(batch_size=8, seed=3, host_id=0,
                                       num_hosts=2))
    h1 = TaskLoader(spec, LoaderConfig(batch_size=8, seed=3, host_id=1,
                                       num_hosts=2))
    bf, b0, b1 = next(full), next(h0), next(h1)
    assert (np.concatenate([b0["tokens"], b1["tokens"]]) ==
            bf["tokens"]).all()


def test_task_similarity_structure():
    a = TaskSpec("shift", 1, 32)
    b = TaskSpec("shift", 2, 32)
    c = TaskSpec("xor", 1, 32)
    assert task_similarity(a, a) == 1.0
    assert 0 < task_similarity(a, b) < 1
    assert task_similarity(a, c) == 0.0


@settings(max_examples=20, deadline=None)
@given(family=st.sampled_from(FAMILIES), param=st.integers(0, 9),
       seed=st.integers(0, 999))
def test_family_property_bounded_alphabet(family, param, seed):
    x = np.random.default_rng(seed).integers(0, 32, size=(3, 8))
    y = _apply_family(family, param, x, 32)
    assert ((y >= 0) & (y < 32)).all()
