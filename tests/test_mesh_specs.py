"""Sharding-spec construction logic (pure, mesh duck-typed)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import mesh as mesh_lib


class FakeMesh:
    """Duck-typed mesh: only axis_names and shape are consulted by the
    spec builders."""

    def __init__(self, shape: dict):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_data_axes():
    assert mesh_lib.data_axes(SINGLE) == ("data",)
    assert mesh_lib.data_axes(MULTI) == ("pod", "data")


def test_batch_spec_divisible():
    assert mesh_lib.batch_spec(SINGLE, 256, 2) == P("data", None)
    assert mesh_lib.batch_spec(MULTI, 256, 2) == P(("pod", "data"), None)


def test_batch_spec_falls_back_to_sequence():
    # batch=1 can't shard; the 512k sequence dim takes the data axes
    spec = mesh_lib.batch_spec(SINGLE, 1, 2, seq_dim=1, seq_len=524288)
    assert spec == P(None, "data")
    spec = mesh_lib.batch_spec(MULTI, 1, 2, seq_dim=1, seq_len=524288)
    assert spec == P(None, ("pod", "data"))


def test_batch_spec_indivisible_stays_replicated():
    assert mesh_lib.batch_spec(SINGLE, 3, 2) == P(None, None)


def test_cache_specs_kv_layout():
    # (layers, B, L, Hkv, hd): B over data, heads over model if divisible
    cache = {"kv": jax.ShapeDtypeStruct((28, 128, 32768, 16, 128),
                                        jnp.bfloat16)}
    specs = mesh_lib.cache_partition_specs(cache, SINGLE)
    assert specs["kv"] == P(None, "data", None, "model", None)


def test_cache_specs_head_indivisible_uses_hd():
    cache = {"kv": jax.ShapeDtypeStruct((28, 128, 32768, 10, 128),
                                        jnp.bfloat16)}
    specs = mesh_lib.cache_partition_specs(cache, SINGLE)
    assert specs["kv"] == P(None, "data", None, None, "model")


def test_cache_specs_batch1_shards_length():
    cache = {"kv": jax.ShapeDtypeStruct((28, 1, 524288, 16, 128),
                                        jnp.bfloat16)}
    specs = mesh_lib.cache_partition_specs(cache, SINGLE)
    assert specs["kv"] == P(None, None, "data", "model", None)


def test_production_mesh_requires_512_devices():
    if len(jax.devices()) < 512:
        with pytest.raises(Exception):
            mesh_lib.make_production_mesh(multi_pod=True)


def test_long_context_window_policy():
    from repro.config import INPUT_SHAPES
    from repro.configs import get_config
    from repro.launch.steps import model_for_shape

    phi = get_config("phi3-medium-14b")
    long = INPUT_SHAPES["long_500k"]
    assert model_for_shape(phi, long).sliding_window == 8192
    # SSM archs keep their native recurrence (no window)
    rwkv = get_config("rwkv6-7b")
    assert model_for_shape(rwkv, long).sliding_window == 0
    # MLA's compressed cache is already O(L): no window
    ds = get_config("deepseek-v2-236b")
    assert model_for_shape(ds, long).sliding_window == 0
    # other shapes untouched
    assert model_for_shape(phi, INPUT_SHAPES["train_4k"]).sliding_window == 0
