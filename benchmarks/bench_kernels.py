"""Kernel-level benchmark: the fused score-CE path vs the naive and
chunked XLA paths — wall time on CPU (XLA paths) and an analytic HBM
traffic comparison for the TPU target."""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks.common import fmt, save_result, table


def ce_paths(T: int = 2048, D: int = 256, V: int = 8192,
             iters: int = 5) -> Dict:
    import jax
    import jax.numpy as jnp

    key = jax.random.key(0)
    h = jax.random.normal(key, (T, D), jnp.float32)
    e = jax.random.normal(jax.random.fold_in(key, 1), (V, D),
                          jnp.float32) * 0.05
    lab = jax.random.randint(jax.random.fold_in(key, 2), (T,), 0, V)

    @jax.jit
    def naive(h, e, lab):
        logits = h @ e.T
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[:, None], axis=-1)[:, 0]
        return (logz - gold).sum()

    @jax.jit
    def chunked(h, e, lab):
        def body(acc, xs):
            hc, lc = xs
            logits = hc @ e.T
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lc[:, None], axis=-1)[:, 0]
            return acc + (logz - gold).sum(), None

        nc = 8
        hc = h.reshape(nc, T // nc, D)
        lc = lab.reshape(nc, T // nc)
        acc, _ = jax.lax.scan(body, jnp.zeros(()), (hc, lc))
        return acc

    def bench(fn):
        fn(h, e, lab).block_until_ready()
        t0 = time.time()
        for _ in range(iters):
            fn(h, e, lab).block_until_ready()
        return (time.time() - t0) / iters

    t_naive = bench(naive)
    t_chunked = bench(chunked)
    v1 = float(naive(h, e, lab))
    v2 = float(chunked(h, e, lab))

    # analytic HBM traffic on TPU target (bytes):
    #   naive:   write (T,V) logits f32 + read back for softmax + gather
    #   fused:   stream emb once + hidden once; logits never leave VMEM
    naive_bytes = T * V * 4 * 2 + T * D * 4 + V * D * 4
    fused_bytes = T * D * 4 + V * D * 4 + T * 4
    return {
        "shape": f"T{T} D{D} V{V}",
        "naive_s": t_naive,
        "chunked_s": t_chunked,
        "xla_speedup": t_naive / t_chunked,
        "consistency_err": abs(v1 - v2) / max(abs(v1), 1e-9),
        "tpu_naive_hbm_bytes": naive_bytes,
        "tpu_fused_hbm_bytes": fused_bytes,
        "tpu_traffic_reduction_x": naive_bytes / fused_bytes,
    }


def run(quick: bool = False) -> Dict:
    shapes = [(1024, 128, 4096)] if quick else [
        (1024, 128, 4096), (2048, 256, 8192), (4096, 256, 32768)]
    out = {"score_ce": [ce_paths(*s) for s in shapes]}
    rows = [[r["shape"], fmt(r["naive_s"] * 1e3, 1),
             fmt(r["chunked_s"] * 1e3, 1), fmt(r["xla_speedup"], 2),
             fmt(r["tpu_traffic_reduction_x"], 1),
             f"{r['consistency_err']:.1e}"] for r in out["score_ce"]]
    print(table("score-CE paths: naive vs chunked (CPU ms) + fused-kernel "
                "HBM traffic reduction (TPU analytic)",
                ["shape", "naive ms", "chunked ms", "xla x",
                 "fused HBM x", "err"], rows))
    save_result("kernels", out)
    return out


if __name__ == "__main__":
    run()
