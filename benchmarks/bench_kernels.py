"""Kernel-level benchmark: fused Pallas paths vs the naive XLA paths.

Two sections:

* ``score_ce`` — the Eqn-1 scoring CE (naive vs chunked XLA wall time on
  CPU + analytic HBM traffic of the fused kernel on the TPU target).
* ``decode``  — the serving-side per-step attention: split-KV
  ``flash_decode`` (GQA) and absorbed ``mla_decode`` (DeepSeek-V2 /
  Kimi-K2 latent) vs the unfused XLA decode. Wall times on CPU are
  informational; the gated numbers are the *analytic* HBM bytes per
  decode step from ``repro.roofline.decode`` (deterministic, so a >10%
  regression means the traffic model — i.e. the kernel design — got
  worse, not that CI was noisy) plus a parity error of the real kernel
  in interpret mode.

Writes ``artifacts/bench/kernels.json`` every run; set
``WRITE_BENCH_BASELINE=1`` to refresh the committed ``BENCH_kernels.json``
baseline at the repo root, which ``benchmarks.check_regression`` diffs
in CI (non-blocking).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict

import numpy as np

from benchmarks.common import fmt, save_result, table

ROOT_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_kernels.json")

SPLITS = 8          # split-KV partitions priced + used by the kernels
DECODE_L = 16384    # cache length for the decode sweep


def ce_paths(T: int = 2048, D: int = 256, V: int = 8192,
             iters: int = 5) -> Dict:
    import jax
    import jax.numpy as jnp

    key = jax.random.key(0)
    h = jax.random.normal(key, (T, D), jnp.float32)
    e = jax.random.normal(jax.random.fold_in(key, 1), (V, D),
                          jnp.float32) * 0.05
    lab = jax.random.randint(jax.random.fold_in(key, 2), (T,), 0, V)

    @jax.jit
    def naive(h, e, lab):
        logits = h @ e.T
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[:, None], axis=-1)[:, 0]
        return (logz - gold).sum()

    @jax.jit
    def chunked(h, e, lab):
        def body(acc, xs):
            hc, lc = xs
            logits = hc @ e.T
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lc[:, None], axis=-1)[:, 0]
            return acc + (logz - gold).sum(), None

        nc = 8
        hc = h.reshape(nc, T // nc, D)
        lc = lab.reshape(nc, T // nc)
        acc, _ = jax.lax.scan(body, jnp.zeros(()), (hc, lc))
        return acc

    def bench(fn):
        fn(h, e, lab).block_until_ready()
        t0 = time.time()
        for _ in range(iters):
            fn(h, e, lab).block_until_ready()
        return (time.time() - t0) / iters

    t_naive = bench(naive)
    t_chunked = bench(chunked)
    v1 = float(naive(h, e, lab))
    v2 = float(chunked(h, e, lab))

    # analytic HBM traffic on TPU target (bytes):
    #   naive:   write (T,V) logits f32 + read back for softmax + gather
    #   fused:   stream emb once + hidden once; logits never leave VMEM
    naive_bytes = T * V * 4 * 2 + T * D * 4 + V * D * 4
    fused_bytes = T * D * 4 + V * D * 4 + T * 4
    return {
        "shape": f"T{T} D{D} V{V}",
        "naive_s": t_naive,
        "chunked_s": t_chunked,
        "xla_speedup": t_naive / t_chunked,
        "consistency_err": abs(v1 - v2) / max(abs(v1), 1e-9),
        "tpu_naive_hbm_bytes": naive_bytes,
        "tpu_fused_hbm_bytes": fused_bytes,
        "tpu_traffic_reduction_x": naive_bytes / fused_bytes,
    }


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------


def _bench_jit(fn, *args, iters: int = 5) -> float:
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        fn(*args).block_until_ready()
    return (time.time() - t0) / iters


def gqa_decode_point(name: str, *, B: int, H: int, Hkv: int, hd: int,
                     L: int, iters: int = 5) -> Dict:
    """One GQA decode config: XLA wall time + analytic traffic + a
    kernel parity check on a scaled-down shape (interpret mode)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.flash_decode import flash_decode
    from repro.kernels.ref import flash_decode_ref
    from repro.roofline import HBM_BW, gqa_decode_hbm_bytes

    key = jax.random.key(1)
    q = jax.random.normal(key, (B, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, L, hd),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, L, hd),
                          jnp.float32)
    t_xla = _bench_jit(jax.jit(flash_decode_ref), q, k, v, iters=iters)

    # parity at a CI-friendly scale (interpret mode is a python loop)
    Ls = 512
    out = flash_decode(q, k[:, :, :Ls], v[:, :, :Ls], kv_len=Ls - 3,
                       splits=4, bk=128, interpret=True)
    ref = flash_decode_ref(q, k[:, :, :Ls], v[:, :, :Ls], kv_len=Ls - 3)
    err = float(np.abs(np.asarray(out) - np.asarray(ref)).max())

    traffic = gqa_decode_hbm_bytes(B=B, H=H, Hkv=Hkv, hd=hd, L=L,
                                   splits=SPLITS)
    return {
        "point": f"decode-gqa {name} L{L}",
        "shape": f"B{B} H{H} kv{Hkv} hd{hd} L{L}",
        "xla_cpu_ms": t_xla * 1e3,
        "parity_err": err,
        "naive_hbm_bytes": traffic["naive_bytes"],
        "fused_hbm_bytes": traffic["fused_bytes"],
        "floor_hbm_bytes": traffic["floor_bytes"],
        "reduction_x": traffic["reduction_x"],
        "naive_step_ms": traffic["naive_bytes"] / HBM_BW * 1e3,
        "fused_step_ms": traffic["fused_bytes"] / HBM_BW * 1e3,
    }


def mla_decode_point(name: str, *, B: int, H: int, r: int, rd: int,
                     L: int, scale: float, iters: int = 5) -> Dict:
    """One absorbed-MLA decode config (latent cache, per SNIPPETS §3)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.mla_decode import mla_decode
    from repro.kernels.ref import mla_decode_ref
    from repro.roofline import HBM_BW, mla_decode_hbm_bytes

    key = jax.random.key(2)
    ql = jax.random.normal(key, (B, H, r), jnp.float32) * 0.1
    qp = jax.random.normal(jax.random.fold_in(key, 1), (B, H, rd),
                           jnp.float32)
    ckv = jax.random.normal(jax.random.fold_in(key, 2), (B, L, r),
                            jnp.float32) * 0.1
    kpe = jax.random.normal(jax.random.fold_in(key, 3), (B, L, rd),
                            jnp.float32)
    import functools
    ref = jax.jit(functools.partial(mla_decode_ref, scale=scale))
    t_xla = _bench_jit(ref, ql, qp, ckv, kpe, iters=iters)

    Ls = 512
    out = mla_decode(ql, qp, ckv[:, :Ls], kpe[:, :Ls], scale=scale,
                     kv_len=Ls - 5, splits=4, bk=128, interpret=True)
    want = mla_decode_ref(ql, qp, ckv[:, :Ls], kpe[:, :Ls], scale=scale,
                          kv_len=Ls - 5)
    err = float(np.abs(np.asarray(out) - np.asarray(want)).max())

    traffic = mla_decode_hbm_bytes(B=B, H=H, r=r, rd=rd, L=L, splits=SPLITS)
    return {
        "point": f"decode-mla {name} L{L}",
        "shape": f"B{B} H{H} r{r} rd{rd} L{L}",
        "xla_cpu_ms": t_xla * 1e3,
        "parity_err": err,
        "naive_hbm_bytes": traffic["naive_bytes"],
        "fused_hbm_bytes": traffic["fused_bytes"],
        "floor_hbm_bytes": traffic["floor_bytes"],
        "reduction_x": traffic["reduction_x"],
        "naive_step_ms": traffic["naive_bytes"] / HBM_BW * 1e3,
        "fused_step_ms": traffic["fused_bytes"] / HBM_BW * 1e3,
    }


def decode_sweep(quick: bool = False) -> Dict:
    """GQA + MLA decode configs drawn from the assigned arch registry so
    the priced shapes track the real model dims."""
    from repro.configs import get_config

    L = 2048 if quick else DECODE_L
    B = 2 if quick else 8
    points = []

    gqa_archs = ["qwen2-7b"] if quick else [
        "qwen2-7b", "phi3-medium-14b", "command-r-plus-104b"]
    for arch in gqa_archs:
        cfg = get_config(arch)
        points.append(gqa_decode_point(
            arch, B=B, H=cfg.num_heads, Hkv=cfg.kv_heads(),
            hd=cfg.resolved_head_dim(), L=L))

    mla_archs = ["deepseek-v2-236b"] if quick else [
        "deepseek-v2-236b", "kimi-k2-1t-a32b"]
    for arch in mla_archs:
        cfg = get_config(arch)
        m = cfg.mla
        scale = 1.0 / (m.qk_nope_head_dim + m.qk_rope_head_dim) ** 0.5
        points.append(mla_decode_point(
            arch, B=B, H=cfg.num_heads, r=m.kv_lora_rank,
            rd=m.qk_rope_head_dim, L=L, scale=scale))
    return {"L": L, "B": B, "points": points}


def run(quick: bool = False) -> Dict:
    shapes = [(1024, 128, 4096)] if quick else [
        (1024, 128, 4096), (2048, 256, 8192), (4096, 256, 32768)]
    out = {"score_ce": [ce_paths(*s) for s in shapes]}
    rows = [[r["shape"], fmt(r["naive_s"] * 1e3, 1),
             fmt(r["chunked_s"] * 1e3, 1), fmt(r["xla_speedup"], 2),
             fmt(r["tpu_traffic_reduction_x"], 1),
             f"{r['consistency_err']:.1e}"] for r in out["score_ce"]]
    print(table("score-CE paths: naive vs chunked (CPU ms) + fused-kernel "
                "HBM traffic reduction (TPU analytic)",
                ["shape", "naive ms", "chunked ms", "xla x",
                 "fused HBM x", "err"], rows))

    dec = decode_sweep(quick=quick)
    out["decode"] = dec
    rows = [[p["point"], p["shape"], fmt(p["xla_cpu_ms"], 1),
             fmt(p["naive_step_ms"], 3), fmt(p["fused_step_ms"], 3),
             fmt(p["reduction_x"], 2), f"{p['parity_err']:.1e}"]
            for p in dec["points"]]
    print(table("decode paths: split-KV flash / MLA latent vs naive XLA "
                "(TPU-analytic ms/step @ v5e HBM)",
                ["point", "shape", "xla cpu ms", "naive ms", "fused ms",
                 "HBM x", "parity err"], rows))
    for p in dec["points"]:
        assert p["fused_hbm_bytes"] < p["naive_hbm_bytes"], p["point"]

    # regression-gated doc: deterministic analytic metrics only (lower
    # is better), keyed the way check_regression expects
    doc = {
        "config": {"quick": quick, "splits": SPLITS, "L": dec["L"],
                   "B": dec["B"]},
        "config_keys": ["quick", "splits", "L", "B"],
        "metrics": ["fused_hbm_bytes", "fused_step_ms"],
        "points": {p["point"]: {"total": {
            "fused_hbm_bytes": p["fused_hbm_bytes"],
            "fused_step_ms": p["fused_step_ms"],
            "naive_hbm_bytes": p["naive_hbm_bytes"],
            "reduction_x": p["reduction_x"],
            "parity_err": p["parity_err"],
        }} for p in dec["points"]},
        "score_ce": out["score_ce"],
    }
    save_result("kernels", doc)
    if os.environ.get("WRITE_BENCH_BASELINE"):
        with open(ROOT_JSON, "w") as f:
            json.dump(doc, f, indent=1, default=float)
        print(f"wrote baseline {os.path.abspath(ROOT_JSON)}")
    else:
        print("baseline untouched (set WRITE_BENCH_BASELINE=1 to refresh "
              f"{os.path.abspath(ROOT_JSON)})")
    return doc


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
