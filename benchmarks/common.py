"""Shared benchmark machinery: result recording, tables, ITA measurement."""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

RESULTS_DIR = os.environ.get("REPRO_BENCH_OUT", "artifacts/bench")


def save_result(name: str, payload: Dict[str, Any]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def load_result(name: str) -> Optional[Dict[str, Any]]:
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return None


def table(title: str, headers: List[str], rows: List[List[Any]]) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(headers)]
    out = [f"== {title} =="]
    out.append("  ".join(str(h).ljust(w) for h, w in zip(headers, widths)))
    for r in rows:
        out.append("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(out)


def fmt(x, nd=2):
    if isinstance(x, float):
        return f"{x:.{nd}f}"
    return x


@dataclass
class ITAContext:
    """Everything needed to measure Iterations-To-Accuracy on the real
    testbed: pretrained model + bank + per-task targets.

    The bank used for a query task HOLDS OUT that task's own optimized
    prompts (the paper's premise is transfer from prompts optimized for
    *similar* tasks; the full bank would contain the answer verbatim and
    trivialize ITA to zero)."""
    llm: str
    pre: Any
    bank: Any
    tune_cfg: Any
    targets: Dict[str, float] = field(default_factory=dict)
    _holdout: Dict[str, Any] = field(default_factory=dict)

    def target_for(self, task) -> float:
        """Target loss = near-convergence quality: the task's own
        optimized prompt's score x 1.5 + 0.05 (every init must TUNE to
        reach it; the paper's targets are likewise set so all evaluated
        inits can reach them)."""
        if task.task_id not in self.targets:
            import jax.numpy as jnp

            from repro.data import LoaderConfig, TaskLoader
            from repro.tuning import PromptTuner
            loader = TaskLoader(task, LoaderConfig(
                batch_size=self.tune_cfg.batch_size))
            tuner = PromptTuner(self.pre.model, self.tune_cfg)
            own = tuner.score(
                {"soft_prompt": jnp.asarray(
                    self.pre.task_prompts[task.task_id])},
                self.pre.params,
                loader.eval_batch(self.tune_cfg.eval_samples))
            self.targets[task.task_id] = float(own) * 1.5 + 0.05
        return self.targets[task.task_id]

    def bank_for(self, task):
        """Sub-bank excluding the query task's own prompts + variants."""
        if task.task_id not in self._holdout:
            from repro.core.prompt_bank import PromptBank
            entries = [e for e in self.bank.entries
                       if e.origin != "<evicted>"
                       and not e.origin.startswith(task.task_id + "/")]
            sub = PromptBank(capacity=self.bank.capacity,
                             num_clusters=self.bank.num_clusters,
                             seed=self.bank.seed)
            sub.add_candidates(entries)
            sub.build()
            self._holdout[task.task_id] = sub
        return self._holdout[task.task_id]


def make_ita_context(llm: str, tune_cfg=None, num_clusters: int = 48,
                     variants: int = 4) -> ITAContext:
    from repro.config import TuneConfig
    from repro.core.bank_builder import build_bank_from_pretrain
    from repro.train.pretrain import pretrain

    pre = pretrain(llm, cache=True)
    bank = build_bank_from_pretrain(pre, variants_per_prompt=variants,
                                    num_clusters=num_clusters)
    return ITAContext(llm, pre, bank,
                      tune_cfg or TuneConfig(lr=0.5, batch_size=16,
                                             eval_every=5))


def measure_ita(ctx: ITAContext, task, prompt, *, max_iters=400):
    """Real tuning run until the task's target loss. Returns (iters,
    reached)."""
    import jax.numpy as jnp

    from repro.data import LoaderConfig, TaskLoader
    from repro.tuning import PromptTuner

    loader = TaskLoader(task, LoaderConfig(
        batch_size=ctx.tune_cfg.batch_size))
    tuner = PromptTuner(ctx.pre.model, ctx.tune_cfg)
    res = tuner.tune(ctx.pre.params, loader,
                     {"soft_prompt": jnp.asarray(prompt)},
                     target_loss=ctx.target_for(task), max_iters=max_iters)
    return res["iters"], res["reached"]
