"""Fig 3: inefficiencies of existing systems.

(a) ElasticFlow cluster utilization over time (static pool waste),
(b) INFless instance-init share of end-to-end latency (CDF),
(c) SLO violation vs maximum GPUs for both baselines.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import fmt, save_result, table
from repro.cluster import SimConfig, TraceConfig, clone_jobs, generate_trace, policies


def run(quick: bool = False) -> Dict:
    minutes = 10 if quick else 20
    jobs = generate_trace(TraceConfig(load="medium", seed=0,
                                      minutes=minutes))
    out: Dict = {}

    # (a) ElasticFlow utilization: busy GPUs / provisioned
    ef = policies.build("elasticflow", SimConfig(max_gpus=32))
    res = ef.run(clone_jobs(jobs))
    util = [100.0 * busy / 32 for t, busy in res.util_samples
            if t < minutes * 60]
    out["fig3a_util"] = {
        "mean_util_pct": float(np.mean(util)),
        "p90_util_pct": float(np.percentile(util, 90)),
    }

    # (b) INFless: init share of end-to-end latency
    inf = policies.build("infless", SimConfig(max_gpus=32))
    res = inf.run(clone_jobs(jobs))
    shares = []
    for r in res.records:
        if np.isfinite(r.finish) and r.finish > r.job.submit_time:
            e2e = r.finish - r.job.submit_time
            shares.append(100.0 * (r.init_overhead + r.wait) / e2e)
    out["fig3b_init_share"] = {
        "mean_pct": float(np.mean(shares)),
        "max_pct": float(np.percentile(shares, 99)),
    }

    # (c) violation vs fleet size
    out["fig3c"] = {}
    for gpus in (8, 16, 24, 32):
        row = {}
        for name in ("elasticflow", "infless", "prompttuner"):
            r = policies.build(name, SimConfig(max_gpus=gpus)).run(
                clone_jobs(jobs)).summary()
            row[name] = r["slo_violation_pct"]
        out["fig3c"][str(gpus)] = row

    print(table("Fig 3a — ElasticFlow utilization (paper: ~56 %)",
                ["mean %", "p90 %"],
                [[fmt(out["fig3a_util"]["mean_util_pct"], 1),
                  fmt(out["fig3a_util"]["p90_util_pct"], 1)]]))
    print(table("Fig 3b — INFless init+wait share of e2e latency "
                "(paper: avg 11 %, up to 50 %)",
                ["mean %", "p99 %"],
                [[fmt(out["fig3b_init_share"]["mean_pct"], 1),
                  fmt(out["fig3b_init_share"]["max_pct"], 1)]]))
    rows = [[g, fmt(r["elasticflow"], 1), fmt(r["infless"], 1),
             fmt(r["prompttuner"], 1)] for g, r in out["fig3c"].items()]
    print(table("Fig 3c — SLO violation (%) vs max GPUs (paper: up to 70 %)",
                ["gpus", "EF", "INF", "PT"], rows))
    save_result("inefficiency", out)
    return out


if __name__ == "__main__":
    run()
