"""Table 7: heavy workloads — LLaMA-30B and Qwen7B-R1 (4-GPU tensor-
parallel replicas) on 32 GPUs, plus the 96-GPU large-scale run."""
from __future__ import annotations

from typing import Dict

from benchmarks.common import fmt, save_result, table
from repro.cluster import SimConfig, TraceConfig, clone_jobs, generate_trace, policies

SYSTEMS = ("prompttuner", "infless", "elasticflow")


def run_setting(load: str, gpus: int, scale: float = 1.0, seeds: int = 3,
                minutes: int = 20) -> Dict[str, Dict]:
    out = {s: {"slo_violation_pct": 0.0, "cost_usd": 0.0} for s in SYSTEMS}
    for sd in range(seeds):
        jobs = generate_trace(TraceConfig(load=load, slo_emergence=1.0,
                                          seed=sd, minutes=minutes,
                                          scale=scale))
        for name in SYSTEMS:
            res = policies.build(name, SimConfig(max_gpus=gpus)).run(
                clone_jobs(jobs)).summary()
            out[name]["slo_violation_pct"] += res["slo_violation_pct"] / seeds
            out[name]["cost_usd"] += res["cost_usd"] / seeds
    return out


def run(quick: bool = False) -> Dict:
    seeds = 1 if quick else 3
    minutes = 10 if quick else 20
    out = {
        "llama-30b": run_setting("llama-30b", 32, seeds=seeds,
                                 minutes=minutes),
        "qwen7b-r1": run_setting("qwen7b-r1", 32, seeds=seeds,
                                 minutes=minutes),
        # large-scale: 96 GPUs, medium loads scaled 3x (§6.2 Scalability)
        "large-scale": run_setting("medium", 96, scale=3.0, seeds=seeds,
                                   minutes=minutes),
    }
    rows = []
    for setting, r in out.items():
        rows.append([setting]
                    + [fmt(r[s]["slo_violation_pct"], 1) for s in SYSTEMS]
                    + [fmt(r[s]["cost_usd"], 1) for s in SYSTEMS])
    print(table("Table 7 — heavy workloads (viol % | cost $)",
                ["setting", "PT viol", "INF viol", "EF viol",
                 "PT $", "INF $", "EF $"], rows))
    save_result("heavy", out)
    return out


if __name__ == "__main__":
    run()
