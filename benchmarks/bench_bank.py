"""Fig 9 + Fig 10: Prompt Bank quality — REAL experiments on the testbed.

Fig 9a: score candidate vs ideal candidate (relative ITA).
Fig 9b: score candidate vs induction candidate (ITA speedup per LLM).
Fig 10a: top-1/top-5 cosine similarity CDF of bank activation features.
Fig 10b: cluster-count sweep — selection latency + relative score.

Also calibrates ``bank_over_ideal`` and ``induction_over_bank`` for the
simulator (artifacts/ita_calibration.json).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import (
    fmt,
    make_ita_context,
    measure_ita,
    save_result,
    table,
)


def fig9(llm: str, n_tasks: int = 6, max_iters: int = 400,
         shortlist: int = 5) -> Dict:
    from repro.core.bank_builder import (
        make_score_fn,
        select_induction,
    )

    ctx = make_ita_context(llm)
    rng = np.random.default_rng(1)
    task_ids = rng.choice(len(ctx.pre.tasks), size=n_tasks, replace=False)
    rel_ideal: List[float] = []
    speedup_induction: List[float] = []
    for ti in task_ids:
        task = ctx.pre.tasks[int(ti)]
        bank = ctx.bank_for(task)          # hold out the task's own prompts
        sc = make_score_fn(ctx.pre, task, ctx.tune_cfg)
        pick = bank.lookup(sc)
        ita_score, _ = measure_ita(ctx, task, pick.entry.prompt,
                                   max_iters=max_iters)
        # ideal baseline: shortlist by score, pick best measured ITA
        scored = sorted(
            ((sc(e), e) for e in bank.entries
             if e.origin != "<evicted>"), key=lambda t: t[0])
        best_ita = ita_score
        for s, e in scored[:shortlist]:
            ita_e, _ = measure_ita(ctx, task, e.prompt, max_iters=max_iters)
            best_ita = min(best_ita, ita_e)
        rel_ideal.append(max(best_ita, 1) / max(ita_score, 1))
        # induction baseline: capability scales with testbed LLM size
        capability = {"gpt2-base": 0.25, "gpt2-large": 0.4,
                      "vicuna-7b": 0.55}.get(llm, 0.4)
        ind = select_induction(ctx.pre, task, capability=capability)
        ita_ind, _ = measure_ita(ctx, task, ind, max_iters=max_iters)
        # floor both at 1 iteration: ITA=0 (init already at target) would
        # otherwise produce 0x / inf ratios
        speedup_induction.append(max(ita_ind, 1) / max(ita_score, 1))
    return {
        "llm": llm,
        "rel_ita_vs_ideal": rel_ideal,          # paper: mostly > 0.9
        "mean_rel_ideal": float(np.mean(rel_ideal)),
        "speedup_vs_induction": speedup_induction,  # paper: 1.28-2.8x
        "min_speedup_induction": float(np.min(speedup_induction)),
        "mean_speedup_induction": float(np.mean(speedup_induction)),
    }


def fig10a(llm: str = "gpt2-base") -> Dict:
    ctx = make_ita_context(llm)
    feats = np.stack([e.feature for e in ctx.bank.entries
                      if e.origin != "<evicted>"])
    fn = feats / (np.linalg.norm(feats, axis=-1, keepdims=True) + 1e-12)
    sim = fn @ fn.T
    np.fill_diagonal(sim, -1)
    top1 = np.sort(sim, axis=1)[:, -1]
    top5 = np.sort(sim, axis=1)[:, -5]
    return {
        "llm": llm,
        "top1_median": float(np.median(top1)),
        "top1_p10": float(np.percentile(top1, 10)),
        "top5_median": float(np.median(top5)),
    }


def fig10b(llm: str = "gpt2-base", cluster_counts=(1, 6, 12, 24, 48),
           n_tasks: int = 4) -> Dict:
    from repro.core.bank_builder import (
        build_bank_from_pretrain,
        make_score_fn,
    )
    from repro.train.pretrain import pretrain

    pre = pretrain(llm, cache=True)
    rng = np.random.default_rng(2)
    task_ids = rng.choice(len(pre.tasks), size=n_tasks, replace=False)
    from repro.config import TuneConfig
    tc = TuneConfig(lr=0.5, batch_size=16)
    out = {}
    for k in cluster_counts:
        bank = build_bank_from_pretrain(pre, variants_per_prompt=4,
                                        num_clusters=k)
        lat, scores, evals = [], [], []
        for ti in task_ids:
            sc = make_score_fn(pre, pre.tasks[int(ti)], tc)
            t0 = time.time()
            res = bank.lookup(sc) if k > 1 else bank.lookup_flat(sc)
            lat.append(time.time() - t0)
            scores.append(res.score)
            evals.append(res.evaluations)
        out[str(k)] = {
            "mean_latency_s": float(np.mean(lat)),
            "mean_score": float(np.mean(scores)),
            "mean_evals": float(np.mean(evals)),
        }
    return out


def calibrate(results: Dict) -> str:
    cal_path = os.path.join(os.environ.get("REPRO_ARTIFACTS", "artifacts"),
                            "ita_calibration.json")
    cal = {}
    if os.path.exists(cal_path):
        with open(cal_path) as f:
            cal = json.load(f)
    rel = [r["mean_rel_ideal"] for r in results.values()]
    cal["bank_over_ideal"] = {
        "lo": 1.0, "hi": float(np.clip(1.0 / max(min(rel), 0.4), 1.02, 2.0))}
    cal.setdefault("induction_over_bank", {})
    for llm, r in results.items():
        sp = r["speedup_vs_induction"]
        cal["induction_over_bank"][llm] = {
            "lo": float(np.clip(min(sp), 1.05, 5.0)),
            "hi": float(np.clip(max(sp), 1.2, 6.0)),
        }
    with open(cal_path, "w") as f:
        json.dump(cal, f, indent=1)
    return cal_path


def run(quick: bool = False) -> Dict:
    llms = ["gpt2-base"] if quick else ["gpt2-base", "gpt2-large",
                                        "vicuna-7b"]
    n_tasks = 3 if quick else 6
    max_iters = 250 if quick else 400
    out: Dict = {"fig9": {}}
    for llm in llms:
        out["fig9"][llm] = fig9(llm, n_tasks=n_tasks, max_iters=max_iters,
                                shortlist=3 if quick else 5)
    rows = [[llm, fmt(r["mean_rel_ideal"]), fmt(r["min_speedup_induction"]),
             fmt(r["mean_speedup_induction"])]
            for llm, r in out["fig9"].items()]
    print(table("Fig 9 — score vs ideal (rel ITA, paper >0.9) and vs "
                "induction (speedup, paper 1.28-2.8x)",
                ["llm", "rel ideal", "min spd ind", "mean spd ind"], rows))
    out["fig10a"] = fig10a()
    a = out["fig10a"]
    print(table("Fig 10a — feature similarity CDF",
                ["top1 med", "top1 p10", "top5 med"],
                [[fmt(a["top1_median"], 3), fmt(a["top1_p10"], 3),
                  fmt(a["top5_median"], 3)]]))
    out["fig10b"] = fig10b(cluster_counts=(1, 12, 48) if quick
                           else (1, 6, 12, 24, 48),
                           n_tasks=2 if quick else 4)
    rows = [[k, fmt(v["mean_latency_s"], 2), fmt(v["mean_evals"], 0),
             fmt(v["mean_score"], 3)] for k, v in out["fig10b"].items()]
    print(table("Fig 10b — cluster count sweep",
                ["K", "latency s", "evals", "score"], rows))
    out["calibration"] = calibrate(out["fig9"])
    save_result("bank", out)
    return out


if __name__ == "__main__":
    run()
