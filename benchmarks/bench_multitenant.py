"""Multi-tenant fabric benchmark: the elastic control plane head-to-head.

Sweeps {shard count x placement x elastic on/off} over the bursty
3-tenant mix (``BURSTY_TENANT_MIX``: premium / standard / best-effort
classes, spiky imbalanced arrivals) and records, per point: SLO
violation rate, billed cost, makespan, and wall-clock. Two elastic
variants run at each shard count:

* ``elastic`` — the full control plane (work stealing + queue-pressure
  autoscaling + per-tenant quotas, here a cost cap on the best-effort
  tenant). This is the paper's headline configuration: SLO-aware
  elasticity plus admission control.
* ``elastic-noquota`` — stealing + autoscaling only, same workload
  admitted as the static runs (pure placement-vs-elastic comparison).

The verdict (recorded in ``BENCH_multitenant.json`` at the repo root):
at the largest shard count the full elastic control plane must show a
lower SLO violation rate AND a lower billed cost than every static
placement. ``benchmarks/check_regression.py`` diffs fresh runs against
the committed baseline.

After the fault-free sweep, a **chaos sweep** re-runs the top shard
count under the three hazard profiles from
``repro.cluster.faults.CHAOS_PROFILES`` (crashes / preemptions /
mixed), comparing three recovery postures on the *same* seeded fault
schedule:

* ``static+faults`` — static placement, no control plane: orphans are
  retried from zero iterations, nobody drains or sheds;
* ``elastic-restart`` — the elastic control plane with every
  failure-awareness knob off and no checkpoints (restart-from-zero);
* ``elastic-aware`` — checkpoint/restore on (30 s interval, jobs with
  under 180 s of tuning compute exempt from the write tax) plus
  drain-on-warning, flap quarantine and best-effort load shedding.

The chaos verdict requires ``elastic-aware`` to beat
``elastic-restart`` on SLO violation rate AND billed cost per profile.

After the sweep, one dedicated telemetry-instrumented run of the
headline configuration (largest shard count, full elastic control
plane) prints the SLO-attainment time-series report and drops
``artifacts/obs/run.trace.json`` (Chrome-trace — open at
https://ui.perfetto.dev) plus ``artifacts/obs/run.jsonl`` (timelines +
metric windows + elastic-decision audit log).
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from typing import Dict, Optional

from benchmarks.common import fmt, save_result, table
from repro.cluster import (
    BURSTY_TENANT_MIX,
    CHAOS_PROFILES,
    ClusterFabric,
    ElasticConfig,
    FaultPlane,
    SimConfig,
    TenantQuota,
    clone_jobs,
    generate_tenant_mix,
)

TENANTS = BURSTY_TENANT_MIX
SHARD_COUNTS = (1, 2, 4, 8)
PLACEMENTS = ("llm-affinity", "least-loaded", "hash")
GPUS = 32
# The full control plane caps the best-effort hog's billed spend; its
# overload is shed at admission instead of burning fleet on jobs that
# would violate anyway.
BEST_EFFORT_CAP_USD = 10.0

ROOT_JSON = os.path.join(os.path.dirname(__file__), os.pardir,
                         "BENCH_multitenant.json")


def elastic_config(quota: bool) -> ElasticConfig:
    quotas = ({"initech": TenantQuota(cost_usd=BEST_EFFORT_CAP_USD)}
              if quota else {})
    return ElasticConfig(quotas=quotas)


def run_point(shards: int, placement: str, elastic: Optional[ElasticConfig],
              *, minutes: int, seeds: int,
              policy: str = "prompttuner") -> Dict[str, Dict]:
    acc: Dict[str, Dict[str, float]] = {}
    total: Dict[str, float] = {
        "slo_violation_pct": 0.0, "cost_usd": 0.0, "gpu_seconds": 0.0,
        "makespan_s": 0.0, "jobs": 0.0, "rejections": 0.0,
        "steals": 0.0, "resizes": 0.0, "wall_clock_s": 0.0,
    }
    for sd in range(seeds):
        mix = generate_tenant_mix(TENANTS, minutes=minutes, seed=sd)
        fab = ClusterFabric(SimConfig(max_gpus=GPUS), policy,
                            shards=shards, placement=placement,
                            elastic=elastic)
        t0 = time.perf_counter()
        res = fab.run(clone_jobs(mix))
        total["wall_clock_s"] += (time.perf_counter() - t0) / seeds
        s = res.summary()
        for k in ("slo_violation_pct", "cost_usd", "gpu_seconds",
                  "makespan_s", "jobs"):
            total[k] += s.get(k, 0.0) / seeds
        total["rejections"] += len(fab.rejections) / seeds
        if fab.controller is not None:
            total["steals"] += fab.controller.steals / seeds
            total["resizes"] += fab.controller.resizes / seeds
        for tenant, row in res.summary_by_tenant().items():
            slot = acc.setdefault(tenant, {
                "slo_violation_pct": 0.0, "cost_usd": 0.0,
                "gpu_seconds": 0.0, "jobs": 0.0})
            for k in slot:
                slot[k] += row.get(k, 0.0) / seeds
    return {"by_tenant": acc, "total": total}


# -- chaos sweep -------------------------------------------------------------

BASE_SEED = 0                 # trace + fault-schedule base seed (seed sd
                              # of a point uses BASE_SEED + sd)
CHAOS_CHECKPOINT_S = 30.0     # aware mode's checkpoint interval
# Jobs with under this much tuning compute never snapshot: the write
# tax is paid up front by every job while the resume credit only pays
# out for the few that die mid-flight, so checkpointing short jobs is
# negative expected value (measured: it alone flips the chaos verdict).
CHAOS_CHECKPOINT_MIN_S = 180.0
CHAOS_MODES = ("static+faults", "elastic-restart", "elastic-aware")


def chaos_setup(mode: str):
    """(elastic config, engine checkpoint kwargs) for one recovery
    posture. No quotas anywhere so every mode admits the identical
    workload."""
    if mode == "static+faults":
        return None, {}
    if mode == "elastic-restart":
        return ElasticConfig(drain_on_warning=False,
                             quarantine_enabled=False,
                             shed_enabled=False), {}
    if mode == "elastic-aware":
        return ElasticConfig(), {
            "checkpoint_interval_s": CHAOS_CHECKPOINT_S,
            "checkpoint_min_compute_s": CHAOS_CHECKPOINT_MIN_S,
        }
    raise ValueError(f"unknown chaos mode {mode!r}")


def run_chaos_point(shards: int, profile: str, mode: str, *,
                    minutes: int, seeds: int,
                    policy: str = "prompttuner") -> Dict[str, Dict]:
    from repro.obs import CAUSES, Telemetry

    total: Dict[str, float] = {
        "slo_violation_pct": 0.0, "cost_usd": 0.0, "gpu_seconds": 0.0,
        "makespan_s": 0.0, "jobs": 0.0, "wall_clock_s": 0.0,
        "crashes": 0.0, "preemptions": 0.0, "retries": 0.0,
        "sheds": 0.0, "recoveries": 0.0,
    }
    cause_keys = tuple(f"cause_{c}_pct" for c in CAUSES + ("exec",))
    for k in cause_keys:
        total[k] = 0.0
    forensics = None
    for sd in range(seeds):
        seed = BASE_SEED + sd
        mix = generate_tenant_mix(TENANTS, minutes=minutes, seed=seed)
        ecfg, ckpt_kw = chaos_setup(mode)
        # fresh plane per run, same seed: every mode faces the identical
        # fault schedule, so the comparison isolates the recovery policy
        faults = FaultPlane(hazard=CHAOS_PROFILES[profile], seed=seed)
        fab = ClusterFabric(
            SimConfig(max_gpus=GPUS, **ckpt_kw), policy,
            shards=shards, placement=PLACEMENTS[0], elastic=ecfg,
            faults=faults)
        # recording rides the event stream: results are identical with
        # it on or off (pinned in tests), so instrumenting the chaos
        # sweep costs wall-clock only
        tel = Telemetry().attach(fab)
        t0 = time.perf_counter()
        res = fab.run(clone_jobs(mix))
        total["wall_clock_s"] += (time.perf_counter() - t0) / seeds
        s = res.summary()
        for k in ("slo_violation_pct", "cost_usd", "gpu_seconds",
                  "makespan_s", "jobs"):
            total[k] += s.get(k, 0.0) / seeds
        for k in ("crashes", "preemptions", "retries", "sheds",
                  "recoveries"):
            total[k] += getattr(faults, k) / seeds
        rep = tel.forensics()
        shares = rep.cause_shares()
        for c in CAUSES + ("exec",):
            total[f"cause_{c}_pct"] += 100.0 * shares.get(c, 0.0) / seeds
        if forensics is None:
            # the artifact carries the first seed's full per-job report
            forensics = rep.to_dict()
    return {"total": total, "_forensics": forensics}


OBS_DIR = os.environ.get("REPRO_OBS_OUT", "artifacts/obs")


def export_telemetry(shards: int, *, minutes: int, seed: int = 0,
                     policy: str = "prompttuner") -> Dict[str, float]:
    """One instrumented run of the headline configuration: print the
    SLO-attainment report, export Chrome-trace + JSONL (with the audit
    log), and return the headline counters."""
    from repro.obs import Telemetry, validate_chrome_trace_file

    mix = generate_tenant_mix(TENANTS, minutes=minutes, seed=seed)
    fab = ClusterFabric(SimConfig(max_gpus=GPUS), policy, shards=shards,
                        placement=PLACEMENTS[0],
                        elastic=elastic_config(quota=True))
    tel = Telemetry().attach(fab)
    fab.run(clone_jobs(mix))

    print()
    print(tel.report(title=f"SLO attainment over time "
                           f"[shards={shards}/elastic, seed={seed}]"))
    os.makedirs(OBS_DIR, exist_ok=True)
    trace = tel.export_chrome_trace(os.path.join(OBS_DIR, "run.trace.json"))
    jsonl = tel.export_jsonl(os.path.join(OBS_DIR, "run.jsonl"))
    problems = validate_chrome_trace_file(trace)
    ok = "OK" if not problems else f"INVALID: {problems[:3]}"
    print(f"\nchrome trace -> {trace} ({ok}; open at "
          f"https://ui.perfetto.dev)\njsonl export -> {jsonl} "
          f"({len(tel.audit.entries)} audit entries)")
    return tel.summary_counters()


def run(quick: bool = False) -> Dict:
    minutes = 10 if quick else 20
    seeds = 1 if quick else 2
    shard_counts = (1, 2, 8) if quick else SHARD_COUNTS
    config = {
        "gpus": GPUS, "minutes": minutes, "seeds": seeds,
        "seed": BASE_SEED,
        "best_effort_cap_usd": BEST_EFFORT_CAP_USD,
        "chaos_profiles": sorted(CHAOS_PROFILES),
        "chaos_checkpoint_s": CHAOS_CHECKPOINT_S,
        "chaos_checkpoint_min_s": CHAOS_CHECKPOINT_MIN_S,
        "tenants": {t.name: {"load": t.load, "scale": t.scale,
                             "slo_class": str(t.slo_class),
                             "spike_prob": t.spike_prob,
                             "spike_mult": t.spike_mult}
                    for t in TENANTS},
    }
    # Stable fingerprint of the sweep parameters: when baseline and
    # fresh runs differ, check_regression names the diverging key(s) —
    # seed and config_hash pin the RNG and the whole config shape.
    config["config_hash"] = hashlib.sha256(
        json.dumps(config, sort_keys=True, default=float).encode()
    ).hexdigest()[:12]
    from repro.obs import CAUSES as _CAUSES

    out: Dict[str, Dict] = {
        "config": config,
        "config_keys": ["gpus", "minutes", "seeds", "seed", "config_hash"],
        # gated metrics check_regression diffs (lower is better): the
        # headline pair plus the chaos sweep's per-cause blame shares,
        # so a recovery-policy change that silently shifts violations
        # from (say) retry_backoff to queue_wait flags the diff
        "metrics": ["slo_violation_pct", "cost_usd"]
        + [f"cause_{c}_pct" for c in _CAUSES + ("exec",)],
        "points": {},
    }
    rows = []
    for shards in shard_counts:
        variants = [(p, None, "static") for p in PLACEMENTS
                    if not (shards == 1 and p != PLACEMENTS[0])]
        # elastic always rides on llm-affinity placement (warmth is what
        # stealing exploits); at shards=1 the controller is a no-op and
        # the row doubles as the golden-equivalence check
        variants.append((PLACEMENTS[0], elastic_config(quota=False),
                         "elastic-noquota"))
        variants.append((PLACEMENTS[0], elastic_config(quota=True),
                         "elastic"))
        for placement, ecfg, mode in variants:
            point = run_point(shards, placement, ecfg,
                              minutes=minutes, seeds=seeds)
            out["points"][f"shards{shards}/{placement}/{mode}"] = point
            t = point["total"]
            bt = point["by_tenant"]
            rows.append([
                shards, placement, mode,
                fmt(t["slo_violation_pct"], 1),
                fmt(t["cost_usd"]),
                fmt(t["makespan_s"], 0),
                fmt(t["wall_clock_s"], 1),
                int(round(t["rejections"])),
                int(round(t["steals"])),
                fmt(bt.get("acme", {}).get("slo_violation_pct", 0.0), 1),
                fmt(bt.get("initech", {}).get("slo_violation_pct", 0.0), 1),
            ])
    print(table(
        "Bursty 3-tenant mix - static placements vs elastic control plane",
        ["shards", "placement", "mode", "viol %", "cost $", "mkspan",
         "wall s", "rej", "steals", "prem %", "be %"], rows))

    # -- head-to-head verdict at the largest shard count -----------------------
    top = max(shard_counts)
    statics = {p: out["points"][f"shards{top}/{p}/static"]["total"]
               for p in PLACEMENTS}
    el = out["points"][f"shards{top}/{PLACEMENTS[0]}/elastic"]["total"]
    beats = all(el["slo_violation_pct"] < s["slo_violation_pct"]
                and el["cost_usd"] < s["cost_usd"]
                for s in statics.values())
    out["verdict"] = {
        "at_shards": top,
        "elastic": {k: el[k] for k in ("slo_violation_pct", "cost_usd")},
        "statics": {p: {k: s[k] for k in ("slo_violation_pct", "cost_usd")}
                    for p, s in statics.items()},
        "elastic_beats_every_static": beats,
    }
    word = ("elastic beats every static placement" if beats
            else "ELASTIC DOES NOT DOMINATE")
    print(f"\nverdict @ {top} shards: elastic "
          f"{el['slo_violation_pct']:.1f}% / ${el['cost_usd']:.2f} vs "
          + ", ".join(f"{p} {s['slo_violation_pct']:.1f}%/"
                      f"${s['cost_usd']:.2f}" for p, s in statics.items())
          + f" -> {word}")

    # -- chaos sweep: recovery postures under seeded fault schedules ----------
    from repro.obs import CAUSES

    chaos_rows = []
    chaos_forensics: Dict[str, Dict] = {}
    chaos_profiles = sorted(CHAOS_PROFILES)
    for profile in chaos_profiles:
        for mode in CHAOS_MODES:
            point = run_chaos_point(top, profile, mode,
                                    minutes=minutes, seeds=seeds)
            # the full per-job report goes to the artifact, not the
            # committed baseline (point totals keep the flat shares)
            rep = point.pop("_forensics", None)
            if rep is not None:
                chaos_forensics[f"{profile}/{mode}"] = rep
            out["points"][f"chaos/{profile}/shards{top}/{mode}"] = point
            t = point["total"]
            top_cause = max(CAUSES + ("exec",),
                            key=lambda c: t.get(f"cause_{c}_pct", 0.0))
            chaos_rows.append([
                profile, mode,
                fmt(t["slo_violation_pct"], 1), fmt(t["cost_usd"]),
                fmt(t["makespan_s"], 0), fmt(t["wall_clock_s"], 1),
                int(round(t["crashes"] + t["preemptions"])),
                int(round(t["retries"])), int(round(t["sheds"])),
                f"{top_cause} {t.get(f'cause_{top_cause}_pct', 0.0):.0f}%",
            ])
    print()
    print(table(
        f"Chaos sweep @ {top} shards - recovery postures under "
        "identical fault schedules",
        ["profile", "mode", "viol %", "cost $", "mkspan", "wall s",
         "faults", "retries", "shed", "top blame"], chaos_rows))
    os.makedirs(OBS_DIR, exist_ok=True)
    forensics_path = os.path.join(OBS_DIR, "chaos.forensics.json")
    with open(forensics_path, "w") as f:
        json.dump(chaos_forensics, f, indent=1, default=float)
    print(f"\nchaos forensics (per-job blame, seed {BASE_SEED}) -> "
          f"{forensics_path}")

    # -- chaos verdict: failure-aware elastic vs restart-from-zero ------------
    per_profile = {}
    aware_beats_restart = True
    for profile in chaos_profiles:
        restart = out["points"][
            f"chaos/{profile}/shards{top}/elastic-restart"]["total"]
        aware = out["points"][
            f"chaos/{profile}/shards{top}/elastic-aware"]["total"]
        wins = (aware["slo_violation_pct"] < restart["slo_violation_pct"]
                and aware["cost_usd"] < restart["cost_usd"])
        aware_beats_restart &= wins
        per_profile[profile] = {
            "aware": {k: aware[k] for k in ("slo_violation_pct",
                                            "cost_usd")},
            "restart": {k: restart[k] for k in ("slo_violation_pct",
                                                "cost_usd")},
            "aware_beats_restart": wins,
        }
    out["chaos_verdict"] = {
        "at_shards": top,
        "profiles": per_profile,
        "aware_beats_restart_everywhere": aware_beats_restart,
    }
    word = ("failure-aware elastic beats restart-from-zero on every "
            "profile" if aware_beats_restart
            else "FAILURE-AWARE DOES NOT DOMINATE RESTART-FROM-ZERO")
    print(f"\nchaos verdict @ {top} shards: "
          + ", ".join(
              f"{p} aware {v['aware']['slo_violation_pct']:.1f}%/"
              f"${v['aware']['cost_usd']:.2f} vs restart "
              f"{v['restart']['slo_violation_pct']:.1f}%/"
              f"${v['restart']['cost_usd']:.2f}"
              for p, v in per_profile.items())
          + f" -> {word}")

    out["telemetry"] = export_telemetry(top, minutes=minutes)

    save_result("multitenant", out)
    # The repo-root copy is the committed baseline check_regression
    # diffs against — refresh it only on request so ordinary runs
    # (and CI) never clobber the file they are being compared to.
    if os.environ.get("WRITE_BENCH_BASELINE"):
        with open(ROOT_JSON, "w") as f:
            json.dump(out, f, indent=1, default=float)
        print(f"wrote baseline {os.path.abspath(ROOT_JSON)}")
    else:
        print("baseline untouched (set WRITE_BENCH_BASELINE=1 to refresh "
              f"{os.path.abspath(ROOT_JSON)})")
    return out


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
