"""Multi-tenant fabric benchmark: the elastic control plane head-to-head.

Sweeps {shard count x placement x elastic on/off} over the bursty
3-tenant mix (``BURSTY_TENANT_MIX``: premium / standard / best-effort
classes, spiky imbalanced arrivals) and records, per point: SLO
violation rate, billed cost, makespan, and wall-clock. Two elastic
variants run at each shard count:

* ``elastic`` — the full control plane (work stealing + queue-pressure
  autoscaling + per-tenant quotas, here a cost cap on the best-effort
  tenant). This is the paper's headline configuration: SLO-aware
  elasticity plus admission control.
* ``elastic-noquota`` — stealing + autoscaling only, same workload
  admitted as the static runs (pure placement-vs-elastic comparison).

The verdict (recorded in ``BENCH_multitenant.json`` at the repo root):
at the largest shard count the full elastic control plane must show a
lower SLO violation rate AND a lower billed cost than every static
placement. ``benchmarks/check_regression.py`` diffs fresh runs against
the committed baseline.

After the sweep, one dedicated telemetry-instrumented run of the
headline configuration (largest shard count, full elastic control
plane) prints the SLO-attainment time-series report and drops
``artifacts/obs/run.trace.json`` (Chrome-trace — open at
https://ui.perfetto.dev) plus ``artifacts/obs/run.jsonl`` (timelines +
metric windows + elastic-decision audit log).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, Optional

from benchmarks.common import fmt, save_result, table
from repro.cluster import (
    BURSTY_TENANT_MIX,
    ClusterFabric,
    ElasticConfig,
    SimConfig,
    TenantQuota,
    clone_jobs,
    generate_tenant_mix,
)

TENANTS = BURSTY_TENANT_MIX
SHARD_COUNTS = (1, 2, 4, 8)
PLACEMENTS = ("llm-affinity", "least-loaded", "hash")
GPUS = 32
# The full control plane caps the best-effort hog's billed spend; its
# overload is shed at admission instead of burning fleet on jobs that
# would violate anyway.
BEST_EFFORT_CAP_USD = 10.0

ROOT_JSON = os.path.join(os.path.dirname(__file__), os.pardir,
                         "BENCH_multitenant.json")


def elastic_config(quota: bool) -> ElasticConfig:
    quotas = ({"initech": TenantQuota(cost_usd=BEST_EFFORT_CAP_USD)}
              if quota else {})
    return ElasticConfig(quotas=quotas)


def run_point(shards: int, placement: str, elastic: Optional[ElasticConfig],
              *, minutes: int, seeds: int,
              policy: str = "prompttuner") -> Dict[str, Dict]:
    acc: Dict[str, Dict[str, float]] = {}
    total: Dict[str, float] = {
        "slo_violation_pct": 0.0, "cost_usd": 0.0, "gpu_seconds": 0.0,
        "makespan_s": 0.0, "jobs": 0.0, "rejections": 0.0,
        "steals": 0.0, "resizes": 0.0, "wall_clock_s": 0.0,
    }
    for sd in range(seeds):
        mix = generate_tenant_mix(TENANTS, minutes=minutes, seed=sd)
        fab = ClusterFabric(SimConfig(max_gpus=GPUS), policy,
                            shards=shards, placement=placement,
                            elastic=elastic)
        t0 = time.perf_counter()
        res = fab.run(clone_jobs(mix))
        total["wall_clock_s"] += (time.perf_counter() - t0) / seeds
        s = res.summary()
        for k in ("slo_violation_pct", "cost_usd", "gpu_seconds",
                  "makespan_s", "jobs"):
            total[k] += s.get(k, 0.0) / seeds
        total["rejections"] += len(fab.rejections) / seeds
        if fab.controller is not None:
            total["steals"] += fab.controller.steals / seeds
            total["resizes"] += fab.controller.resizes / seeds
        for tenant, row in res.summary_by_tenant().items():
            slot = acc.setdefault(tenant, {
                "slo_violation_pct": 0.0, "cost_usd": 0.0,
                "gpu_seconds": 0.0, "jobs": 0.0})
            for k in slot:
                slot[k] += row.get(k, 0.0) / seeds
    return {"by_tenant": acc, "total": total}


OBS_DIR = os.environ.get("REPRO_OBS_OUT", "artifacts/obs")


def export_telemetry(shards: int, *, minutes: int, seed: int = 0,
                     policy: str = "prompttuner") -> Dict[str, float]:
    """One instrumented run of the headline configuration: print the
    SLO-attainment report, export Chrome-trace + JSONL (with the audit
    log), and return the headline counters."""
    from repro.obs import Telemetry, validate_chrome_trace_file

    mix = generate_tenant_mix(TENANTS, minutes=minutes, seed=seed)
    fab = ClusterFabric(SimConfig(max_gpus=GPUS), policy, shards=shards,
                        placement=PLACEMENTS[0],
                        elastic=elastic_config(quota=True))
    tel = Telemetry().attach(fab)
    fab.run(clone_jobs(mix))

    print()
    print(tel.report(title=f"SLO attainment over time "
                           f"[shards={shards}/elastic, seed={seed}]"))
    os.makedirs(OBS_DIR, exist_ok=True)
    trace = tel.export_chrome_trace(os.path.join(OBS_DIR, "run.trace.json"))
    jsonl = tel.export_jsonl(os.path.join(OBS_DIR, "run.jsonl"))
    problems = validate_chrome_trace_file(trace)
    ok = "OK" if not problems else f"INVALID: {problems[:3]}"
    print(f"\nchrome trace -> {trace} ({ok}; open at "
          f"https://ui.perfetto.dev)\njsonl export -> {jsonl} "
          f"({len(tel.audit.entries)} audit entries)")
    return tel.summary_counters()


def run(quick: bool = False) -> Dict:
    minutes = 10 if quick else 20
    seeds = 1 if quick else 2
    shard_counts = (1, 2, 8) if quick else SHARD_COUNTS
    out: Dict[str, Dict] = {
        "config": {
            "gpus": GPUS, "minutes": minutes, "seeds": seeds,
            "best_effort_cap_usd": BEST_EFFORT_CAP_USD,
            "tenants": {t.name: {"load": t.load, "scale": t.scale,
                                 "slo_class": str(t.slo_class),
                                 "spike_prob": t.spike_prob,
                                 "spike_mult": t.spike_mult}
                        for t in TENANTS},
        },
        "points": {},
    }
    rows = []
    for shards in shard_counts:
        variants = [(p, None, "static") for p in PLACEMENTS
                    if not (shards == 1 and p != PLACEMENTS[0])]
        # elastic always rides on llm-affinity placement (warmth is what
        # stealing exploits); at shards=1 the controller is a no-op and
        # the row doubles as the golden-equivalence check
        variants.append((PLACEMENTS[0], elastic_config(quota=False),
                         "elastic-noquota"))
        variants.append((PLACEMENTS[0], elastic_config(quota=True),
                         "elastic"))
        for placement, ecfg, mode in variants:
            point = run_point(shards, placement, ecfg,
                              minutes=minutes, seeds=seeds)
            out["points"][f"shards{shards}/{placement}/{mode}"] = point
            t = point["total"]
            bt = point["by_tenant"]
            rows.append([
                shards, placement, mode,
                fmt(t["slo_violation_pct"], 1),
                fmt(t["cost_usd"]),
                fmt(t["makespan_s"], 0),
                fmt(t["wall_clock_s"], 1),
                int(round(t["rejections"])),
                int(round(t["steals"])),
                fmt(bt.get("acme", {}).get("slo_violation_pct", 0.0), 1),
                fmt(bt.get("initech", {}).get("slo_violation_pct", 0.0), 1),
            ])
    print(table(
        "Bursty 3-tenant mix - static placements vs elastic control plane",
        ["shards", "placement", "mode", "viol %", "cost $", "mkspan",
         "wall s", "rej", "steals", "prem %", "be %"], rows))

    # -- head-to-head verdict at the largest shard count -----------------------
    top = max(shard_counts)
    statics = {p: out["points"][f"shards{top}/{p}/static"]["total"]
               for p in PLACEMENTS}
    el = out["points"][f"shards{top}/{PLACEMENTS[0]}/elastic"]["total"]
    beats = all(el["slo_violation_pct"] < s["slo_violation_pct"]
                and el["cost_usd"] < s["cost_usd"]
                for s in statics.values())
    out["verdict"] = {
        "at_shards": top,
        "elastic": {k: el[k] for k in ("slo_violation_pct", "cost_usd")},
        "statics": {p: {k: s[k] for k in ("slo_violation_pct", "cost_usd")}
                    for p, s in statics.items()},
        "elastic_beats_every_static": beats,
    }
    word = ("elastic beats every static placement" if beats
            else "ELASTIC DOES NOT DOMINATE")
    print(f"\nverdict @ {top} shards: elastic "
          f"{el['slo_violation_pct']:.1f}% / ${el['cost_usd']:.2f} vs "
          + ", ".join(f"{p} {s['slo_violation_pct']:.1f}%/"
                      f"${s['cost_usd']:.2f}" for p, s in statics.items())
          + f" -> {word}")

    out["telemetry"] = export_telemetry(top, minutes=minutes)

    save_result("multitenant", out)
    # The repo-root copy is the committed baseline check_regression
    # diffs against — refresh it only on request so ordinary runs
    # (and CI) never clobber the file they are being compared to.
    if os.environ.get("WRITE_BENCH_BASELINE"):
        with open(ROOT_JSON, "w") as f:
            json.dump(out, f, indent=1, default=float)
        print(f"wrote baseline {os.path.abspath(ROOT_JSON)}")
    else:
        print("baseline untouched (set WRITE_BENCH_BASELINE=1 to refresh "
              f"{os.path.abspath(ROOT_JSON)})")
    return out


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
