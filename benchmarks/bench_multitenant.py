"""Multi-tenant fabric benchmark: per-tenant SLO violation and billed
cost under a 3-tenant mixed trace (premium / standard / best-effort
classes), swept across shard counts and placement strategies.

What it shows:

* class differentiation — the priority-aware admission order should buy
  the premium tenant a lower violation rate than best-effort at equal
  fleet size;
* sharding cost — fragmenting one fleet into N isolated shards trades
  consolidation (runtime reuse, statistical multiplexing) for isolation;
  ``llm-affinity`` placement recovers most of the reuse, ``hash`` loses
  it.
"""
from __future__ import annotations

from typing import Dict

from benchmarks.common import fmt, save_result, table
from repro.cluster import (
    ClusterFabric,
    DEFAULT_TENANT_MIX,
    SHARED_POOL,
    SimConfig,
    clone_jobs,
    generate_tenant_mix,
)

TENANTS = DEFAULT_TENANT_MIX

SHARD_COUNTS = (1, 2, 4)
PLACEMENTS = ("llm-affinity", "least-loaded", "hash")


def run_point(shards: int, placement: str, *, gpus: int, minutes: int,
              seeds: int, policy: str = "prompttuner") -> Dict[str, Dict]:
    acc: Dict[str, Dict[str, float]] = {}
    total: Dict[str, float] = {"slo_violation_pct": 0.0, "cost_usd": 0.0,
                               "gpu_seconds": 0.0}
    for sd in range(seeds):
        mix = generate_tenant_mix(TENANTS, minutes=minutes, seed=sd)
        fab = ClusterFabric(SimConfig(max_gpus=gpus), policy,
                            shards=shards, placement=placement)
        res = fab.run(clone_jobs(mix))
        s = res.summary()
        for k in total:
            total[k] += s.get(k, 0.0) / seeds
        for tenant, row in res.summary_by_tenant().items():
            slot = acc.setdefault(tenant, {
                "slo_violation_pct": 0.0, "cost_usd": 0.0,
                "gpu_seconds": 0.0, "jobs": 0.0})
            for k in slot:
                slot[k] += row.get(k, 0.0) / seeds
    return {"by_tenant": acc, "total": total}


def run(quick: bool = False) -> Dict:
    minutes = 5 if quick else 20
    seeds = 1 if quick else 3
    gpus = 32
    out: Dict[str, Dict] = {
        "tenants": {t.name: {"load": t.load, "scale": t.scale,
                             "slo_class": str(t.slo_class)}
                    for t in TENANTS},
        "points": {},
    }
    rows = []
    for shards in SHARD_COUNTS:
        for placement in PLACEMENTS:
            if shards == 1 and placement != PLACEMENTS[0]:
                continue               # placement is moot with one shard
            point = run_point(shards, placement, gpus=gpus,
                              minutes=minutes, seeds=seeds)
            out["points"][f"shards{shards}/{placement}"] = point
            bt = point["by_tenant"]
            rows.append([
                shards, placement,
                fmt(bt.get("acme", {}).get("slo_violation_pct", 0.0), 1),
                fmt(bt.get("globex", {}).get("slo_violation_pct", 0.0), 1),
                fmt(bt.get("initech", {}).get("slo_violation_pct", 0.0), 1),
                # tenant revenue only: the (shared-pool) row is idle
                # capacity attributable to no tenant
                fmt(sum(v["cost_usd"] for t, v in bt.items()
                        if t != SHARED_POOL)),
                fmt(point["total"]["cost_usd"]),
            ])
    print(table(
        "Multi-tenant fabric — per-tenant SLO violation (%) and billing",
        ["shards", "placement", "acme(prem)", "globex(std)",
         "initech(be)", "billed $", "fleet $"], rows))
    save_result("multitenant", out)
    return out


if __name__ == "__main__":
    run()
