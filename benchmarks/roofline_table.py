"""§Roofline table renderer: reads artifacts/dryrun/*.jsonl (written by
repro.launch.dryrun) and prints the per-(arch x shape x mesh) three-term
roofline with dominant bottleneck and useful-FLOPs ratio."""
from __future__ import annotations

import json
import os
from typing import Dict, List

from benchmarks.common import fmt, save_result, table

DRYRUN_DIR = os.path.join(os.environ.get("REPRO_ARTIFACTS", "artifacts"),
                          "dryrun")


def load_records(mesh: str) -> List[Dict]:
    path = os.path.join(DRYRUN_DIR, f"{mesh}.jsonl")
    if not os.path.exists(path):
        return []
    recs = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            recs[(r["arch"], r["shape"])] = r   # last write wins
    return list(recs.values())


def rows_for(recs: List[Dict]) -> List[List]:
    rows = []
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    for r in sorted(recs, key=lambda r: (r["arch"],
                                         order.get(r["shape"], 9))):
        if "error" in r:
            rows.append([r["arch"], r["shape"], "ERROR", "", "", "", "", ""])
            continue
        t = r["roofline"]
        m = r["memory"]
        rows.append([
            r["arch"], r["shape"],
            fmt(t["compute_s"], 3), fmt(t["memory_s"], 3),
            fmt(t["collective_s"], 3), t["dominant"],
            fmt(r.get("useful_flops_ratio", 0.0), 2),
            fmt((m.get("argument_size_in_bytes", 0)
                 + m.get("temp_size_in_bytes", 0)) / 1e9, 1),
        ])
    return rows


def run(quick: bool = False) -> Dict:
    out = {}
    for mesh in ("single", "multi"):
        recs = load_records(mesh)
        out[mesh] = {"n": len(recs),
                     "errors": sum(1 for r in recs if "error" in r)}
        if recs:
            print(table(
                f"§Roofline — {mesh} pod "
                f"({'16x16' if mesh == 'single' else '2x16x16'}), "
                "seconds per step",
                ["arch", "shape", "comp", "mem", "coll", "dominant",
                 "useful", "HBM GB/dev"],
                rows_for(recs)))
    save_result("roofline", out)
    return out


if __name__ == "__main__":
    run()
