"""Fig 2 + Table 2: LPT workload characterization.

(a) end-to-end time breakdown (compute / comm / allocation),
(b) trace spikiness (max rpm / mean rpm ~ 5x),
(c) ITA CDF over 20 random initial prompts — REAL tuning runs on the
    testbed LLM; this also CALIBRATES the simulator
    (artifacts/ita_calibration.json).
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks.common import fmt, make_ita_context, measure_ita, save_result, table


def time_breakdown(llm: str = "gpt2-base", iters: int = 30) -> Dict:
    """Measured compute time per iteration vs (modeled) comm + alloc.

    Comm payload per iteration = the prompt gradient (P x d floats) —
    the actual all-reduce payload in multi-GPU LPT. At A100 NVLink-class
    600 GB/s, that's sub-microsecond vs tens-of-ms steps: the paper's
    0.4-0.5 % comm share comes from launch/sync overheads, which we take
    from its Fig 2a as the model constant (0.005)."""
    import jax
    import jax.numpy as jnp

    from repro.data import LoaderConfig, TaskLoader
    from repro.train.pretrain import pretrain
    from repro.tuning import PromptTuner
    from repro.config import TuneConfig
    from repro.core.jobs import LLM_PROFILES

    pre = pretrain(llm, cache=True)
    tc = TuneConfig(batch_size=16)
    tuner = PromptTuner(pre.model, tc)
    loader = TaskLoader(pre.tasks[0], LoaderConfig(batch_size=16))
    pp = tuner.init_prompt(pre.params, jax.random.key(0))
    opt = tuner.init_opt(pp)
    # warmup/compile
    pp, opt, _ = tuner.step(pp, opt, pre.params, next(loader))
    t0 = time.time()
    for _ in range(iters):
        pp, opt, _ = tuner.step(pp, opt, pre.params, next(loader))
    jax.block_until_ready(pp["soft_prompt"])
    step_s = (time.time() - t0) / iters
    payload = pp["soft_prompt"].size * 4
    prof = LLM_PROFILES.get(llm)
    comm_frac = prof.comm_frac if prof else 0.005
    alloc_s = prof.warm_overhead if prof else 1.0
    n_iters = 200
    total = n_iters * step_s * (1 + comm_frac) + alloc_s
    return {
        "llm": llm,
        "step_s": step_s,
        "grad_payload_bytes": int(payload),
        "compute_pct": 100 * n_iters * step_s / total,
        "comm_pct": 100 * n_iters * step_s * comm_frac / total,
        "alloc_pct": 100 * alloc_s / total,
    }


def trace_pattern(seed: int = 0) -> Dict:
    from repro.cluster import TraceConfig, generate_trace

    jobs = generate_trace(TraceConfig(load="medium", seed=seed, minutes=20))
    per_min = np.zeros(20)
    for j in jobs:
        per_min[min(int(j.submit_time // 60), 19)] += 1
    return {
        "jobs": len(jobs),
        "mean_rpm": float(per_min.mean()),
        "max_rpm": float(per_min.max()),
        "spike_ratio": float(per_min.max() / max(per_min.mean(), 1e-9)),
        "per_min": per_min.tolist(),
    }


def ita_cdf(llm: str = "gpt2-base", n_prompts: int = 20, n_tasks: int = 3,
            max_iters: int = 400, calibrate: bool = True) -> Dict:
    """Fig 2c: ITA distribution over random initial prompts, REAL runs."""
    import json
    import os

    from repro.core.bank_builder import select_manual

    ctx = make_ita_context(llm)
    rng = np.random.default_rng(0)
    task_ids = rng.choice(len(ctx.pre.tasks), size=n_tasks, replace=False)
    all_itas = []
    per_task = {}
    for ti in task_ids:
        task = ctx.pre.tasks[int(ti)]
        itas = []
        for p in range(n_prompts):
            prompt = select_manual(ctx.pre, seed=1000 + p)
            iters, reached = measure_ita(ctx, task, prompt,
                                         max_iters=max_iters)
            itas.append(iters)
        per_task[task.task_id] = itas
        all_itas.extend(itas)
    arr = np.asarray(all_itas, float)
    # per-task ratios (targets differ per task; pooling across tasks
    # inflates the spread). Runs capped at max_iters are CENSORED: the
    # true max/min is at least the reported value.
    ratios_med, ratios_max, censored = [], [], 0
    for itas in per_task.values():
        a = np.asarray(itas, float)
        censored += int((a >= max_iters).sum())
        lo = max(a.min(), 1.0)
        ratios_med.append(float(np.median(a) / lo))
        ratios_max.append(float(a.max() / lo))
    stats = {
        "min": float(arr.min()),
        "median": float(np.median(arr)),
        "max": float(arr.max()),
        "median_over_min": float(np.median(ratios_med)),
        "max_over_min": float(np.median(ratios_max)),
        "censored_runs": censored,
        "total_runs": int(arr.size),
        "per_task": per_task,
    }
    if calibrate:
        # write the manual-vs-ideal spread the simulator samples from
        cal_path = os.path.join(
            os.environ.get("REPRO_ARTIFACTS", "artifacts"),
            "ita_calibration.json")
        cal = {}
        if os.path.exists(cal_path):
            with open(cal_path) as f:
                cal = json.load(f)
        # clamp into a sane band: censored runs can inflate the spread
        # far past anything the scheduler could exploit
        cal["manual_over_ideal"] = {
            "lo": float(np.clip(stats["median_over_min"] * 0.8, 1.2, 4.0)),
            "hi": float(np.clip(stats["max_over_min"], 1.7, 6.0)),
        }
        with open(cal_path, "w") as f:
            json.dump(cal, f, indent=1)
        stats["calibration_written"] = cal_path
    return stats


def run(quick: bool = False) -> Dict:
    out = {}
    out["fig2a_breakdown"] = [time_breakdown("gpt2-base")]
    if not quick:
        out["fig2a_breakdown"].append(time_breakdown("gpt2-large"))
    out["fig2b_trace"] = trace_pattern()
    out["fig2c_ita"] = ita_cdf(
        "gpt2-base",
        n_prompts=6 if quick else 20,
        n_tasks=2 if quick else 3,
        max_iters=250 if quick else 400,
    )
    rows = [[b["llm"], fmt(b["step_s"] * 1e3, 1), b["grad_payload_bytes"],
             fmt(b["compute_pct"], 1), fmt(b["comm_pct"], 2),
             fmt(b["alloc_pct"], 1)] for b in out["fig2a_breakdown"]]
    print(table("Fig 2a — time breakdown (%)",
                ["llm", "step_ms", "grad_B", "compute", "comm", "alloc"],
                rows))
    t = out["fig2b_trace"]
    print(table("Fig 2b — trace pattern", ["jobs", "mean_rpm", "max_rpm",
                                           "spike_ratio"],
                [[t["jobs"], fmt(t["mean_rpm"], 1), fmt(t["max_rpm"], 1),
                  fmt(t["spike_ratio"], 2)]]))
    s = out["fig2c_ita"]
    print(table("Fig 2c — ITA over random prompts (paper: med/max "
                "1.7-4.5x min)",
                ["min", "median", "max", "med/min", "max/min"],
                [[s["min"], s["median"], s["max"],
                  fmt(s["median_over_min"]), fmt(s["max_over_min"])]]))
    save_result("characterization", out)
    return out


if __name__ == "__main__":
    run()
