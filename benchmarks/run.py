"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Writes per-benchmark JSON to artifacts/bench/ and prints tables.
"""
from __future__ import annotations

import argparse
import json
import os
import time
import traceback

from benchmarks import (
    bench_ablation,
    bench_bank,
    bench_characterization,
    bench_end2end,
    bench_heavy,
    bench_inefficiency,
    bench_kernels,
    bench_multitenant,
    bench_sweeps,
    bench_table1,
    roofline_table,
)

BENCHES = {
    # ordering matters: characterization + bank CALIBRATE the simulator
    # (artifacts/ita_calibration.json) before the end-to-end runs
    "characterization": bench_characterization,   # Fig 2, Table 2
    "bank": bench_bank,                           # Fig 9, Fig 10
    "inefficiency": bench_inefficiency,           # Fig 3
    "end2end": bench_end2end,                     # Fig 7
    "heavy": bench_heavy,                         # Table 7
    "ablation": bench_ablation,                   # Table 8, Fig 8a/b
    "sweeps": bench_sweeps,                       # Fig 8c/d
    "multitenant": bench_multitenant,             # tenant mix x shard counts
    "table1": bench_table1,                       # Table 1
    "kernels": bench_kernels,                     # kernel paths
    "roofline": roofline_table,                   # §Roofline (dry-run)
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()

    names = list(BENCHES)
    if args.only:
        names = [n for n in args.only.split(",") if n in BENCHES]

    summary = {}
    failures = 0
    for name in names:
        print(f"\n#### {name} " + "#" * (60 - len(name)))
        t0 = time.time()
        try:
            BENCHES[name].run(quick=args.quick)
            summary[name] = {"status": "ok",
                             "seconds": round(time.time() - t0, 1)}
        except Exception as e:       # noqa: BLE001 — keep the suite going
            traceback.print_exc()
            summary[name] = {"status": f"FAILED: {e!r}"[:200],
                             "seconds": round(time.time() - t0, 1)}
            failures += 1
    print("\n#### summary " + "#" * 50)
    for name, s in summary.items():
        print(f"{name:20s} {s['status']:10s} {s['seconds']:8.1f}s")
    os.makedirs("artifacts/bench", exist_ok=True)
    with open("artifacts/bench/summary.json", "w") as f:
        json.dump(summary, f, indent=1)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
