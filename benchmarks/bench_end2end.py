"""Fig 7: end-to-end SLO violation + cost vs load (a, b) and vs SLO
emergence S (c, d), PromptTuner vs INFless vs ElasticFlow."""
from __future__ import annotations

from typing import Dict

from benchmarks.common import fmt, save_result, table
from repro.cluster import SimConfig, TraceConfig, clone_jobs, generate_trace, policies

SYSTEMS = ("prompttuner", "infless", "elasticflow")


def run_point(load: str, S: float, *, gpus: int = 32, seed: int = 0,
              minutes: int = 20, seeds: int = 3) -> Dict[str, Dict]:
    out: Dict[str, Dict] = {s: {"slo_violation_pct": 0.0, "cost_usd": 0.0}
                            for s in SYSTEMS}
    for sd in range(seeds):
        jobs = generate_trace(TraceConfig(load=load, slo_emergence=S,
                                          seed=seed + sd, minutes=minutes))
        for name in SYSTEMS:
            res = policies.build(name, SimConfig(max_gpus=gpus)).run(
                clone_jobs(jobs)).summary()
            out[name]["slo_violation_pct"] += res["slo_violation_pct"] / seeds
            out[name]["cost_usd"] += res["cost_usd"] / seeds
    return out


def run(quick: bool = False) -> Dict:
    minutes = 10 if quick else 20
    seeds = 2 if quick else 3
    out = {"vs_load": {}, "vs_emergence": {}}
    for load in ("low", "medium", "high"):
        out["vs_load"][load] = run_point(load, 1.0, minutes=minutes,
                                         seeds=seeds)
    for S in (0.5, 1.0, 1.5):
        out["vs_emergence"][str(S)] = run_point("medium", S,
                                                minutes=minutes, seeds=seeds)

    rows = []
    for load, r in out["vs_load"].items():
        rows.append([load] + [fmt(r[s]["slo_violation_pct"], 1)
                              for s in SYSTEMS]
                    + [fmt(r[s]["cost_usd"]) for s in SYSTEMS])
    print(table("Fig 7a/b — SLO violation (%) and cost ($) vs load",
                ["load", "PT viol", "INF viol", "EF viol",
                 "PT $", "INF $", "EF $"], rows))
    rows = []
    for S, r in out["vs_emergence"].items():
        rows.append([S] + [fmt(r[s]["slo_violation_pct"], 1)
                           for s in SYSTEMS]
                    + [fmt(r[s]["cost_usd"]) for s in SYSTEMS])
    print(table("Fig 7c/d — SLO violation (%) and cost ($) vs emergence S",
                ["S", "PT viol", "INF viol", "EF viol",
                 "PT $", "INF $", "EF $"], rows))

    # headline ratios (paper: up to 4.0x/7.9x violation, 1.6x/4.5x cost)
    worst = out["vs_emergence"]["0.5"]
    pt = worst["prompttuner"]
    out["headline"] = {
        "viol_reduction_vs_infless": (worst["infless"]["slo_violation_pct"]
                                      / max(pt["slo_violation_pct"], 0.1)),
        "viol_reduction_vs_elasticflow": (
            worst["elasticflow"]["slo_violation_pct"]
            / max(pt["slo_violation_pct"], 0.1)),
        "cost_reduction_vs_infless": (worst["infless"]["cost_usd"]
                                      / max(pt["cost_usd"], 1e-6)),
        "cost_reduction_vs_elasticflow": (worst["elasticflow"]["cost_usd"]
                                          / max(pt["cost_usd"], 1e-6)),
    }
    h = out["headline"]
    print(table("Headline ratios @ S=0.5 (paper: 4.0x / 7.9x viol; "
                "1.6x / 4.5x cost)",
                ["viol vs INF", "viol vs EF", "cost vs INF", "cost vs EF"],
                [[fmt(h["viol_reduction_vs_infless"]),
                  fmt(h["viol_reduction_vs_elasticflow"]),
                  fmt(h["cost_reduction_vs_infless"]),
                  fmt(h["cost_reduction_vs_elasticflow"])]]))
    save_result("end2end", out)
    return out


if __name__ == "__main__":
    run()
