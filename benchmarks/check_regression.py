"""Diff a fresh BENCH_*.json against the committed baseline.

    PYTHONPATH=src python -m benchmarks.check_regression \
        [--baseline BENCH_multitenant.json] [--fresh artifacts/bench/multitenant.json] \
        [--threshold 0.10]

Compares every shared sweep point on SLO violation rate and billed
cost; a point regresses when the fresh value exceeds the baseline by
more than ``threshold`` (relative, with a small absolute floor so near-
zero baselines don't flag on noise). Exits non-zero when regressions
are found — CI runs this as a non-blocking job, so a red diff flags the
PR without failing the build.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Tuple

METRICS = ("slo_violation_pct", "cost_usd")
ABS_FLOOR = {"slo_violation_pct": 1.0, "cost_usd": 1.0}


def _points(doc: Dict) -> Dict[str, Dict[str, float]]:
    return {name: p.get("total", {}) for name, p in
            doc.get("points", {}).items()}


def compare(baseline: Dict, fresh: Dict,
            threshold: float) -> List[Tuple[str, str, float, float]]:
    """Returns (point, metric, base, new) for every regression."""
    base_pts = _points(baseline)
    fresh_pts = _points(fresh)
    regressions = []
    for name in sorted(set(base_pts) & set(fresh_pts)):
        for metric in METRICS:
            b = base_pts[name].get(metric)
            f = fresh_pts[name].get(metric)
            if b is None or f is None:
                continue
            if f > b * (1.0 + threshold) + ABS_FLOOR[metric] * threshold:
                regressions.append((name, metric, b, f))
    return regressions


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_multitenant.json")
    ap.add_argument("--fresh",
                    default=os.path.join("artifacts", "bench",
                                         "multitenant.json"))
    ap.add_argument("--threshold", type=float, default=0.10)
    args = ap.parse_args(argv)

    if not os.path.exists(args.baseline):
        print(f"no committed baseline at {args.baseline}; nothing to diff")
        return 0
    if not os.path.exists(args.fresh):
        print(f"no fresh result at {args.fresh}; run the benchmark first")
        return 0
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)

    base_cfg = baseline.get("config", {})
    fresh_cfg = fresh.get("config", {})
    comparable = all(base_cfg.get(k) == fresh_cfg.get(k)
                     for k in ("gpus", "minutes", "seeds"))
    if not comparable:
        print("baseline and fresh runs use different sweep configs "
              f"(baseline {base_cfg.get('gpus')}g/{base_cfg.get('minutes')}m/"
              f"{base_cfg.get('seeds')}s vs fresh {fresh_cfg.get('gpus')}g/"
              f"{fresh_cfg.get('minutes')}m/{fresh_cfg.get('seeds')}s); "
              "skipping the diff")
        return 0

    regressions = compare(baseline, fresh, args.threshold)
    shared = len(set(_points(baseline)) & set(_points(fresh)))
    if not regressions:
        print(f"OK: no >{args.threshold:.0%} regressions across "
              f"{shared} shared points ({', '.join(METRICS)})")
        return 0
    print(f"REGRESSIONS (> {args.threshold:.0%} over baseline):")
    for name, metric, b, f in regressions:
        print(f"  {name}: {metric} {b:.2f} -> {f:.2f} "
              f"(+{(f - b) / max(b, 1e-9):.0%})")
    return 1


if __name__ == "__main__":
    sys.exit(main())
