"""Diff fresh BENCH_*.json runs against the committed baselines.

    PYTHONPATH=src python -m benchmarks.check_regression \
        [--pair BENCH_multitenant.json:artifacts/bench/multitenant.json] \
        [--pair BENCH_kernels.json:artifacts/bench/kernels.json] \
        [--glob 'BENCH_*.json'] [--threshold 0.10]

With no ``--pair`` the default glob discovers every committed
``BENCH_<name>.json`` at the repo root and pairs it with the fresh run
at ``artifacts/bench/<name>.json`` (honoring ``REPRO_BENCH_OUT``).

Each baseline doc declares its own gated metrics (top-level
``"metrics"``, lower-is-better; default: the multitenant pair of SLO
violation rate and billed cost) and the config keys that must match for
the runs to be comparable (``"config_keys"``; mismatched sweep configs
skip the diff instead of flagging). A point regresses when the fresh
value exceeds the baseline by more than ``threshold`` (relative, with a
small absolute floor so near-zero baselines don't flag on noise). Every
comparable pair also prints a per-metric delta table (mean over shared
points + worst single-point move) so a within-threshold run still shows
its drift. Exits non-zero when any pair regresses — CI runs this as a
non-blocking job, so a red diff flags the PR without failing the build.
"""
from __future__ import annotations

import argparse
import glob as globlib
import json
import os
import sys
from typing import Dict, List, Sequence, Tuple

DEFAULT_METRICS = ("slo_violation_pct", "cost_usd")
DEFAULT_CONFIG_KEYS = ("gpus", "minutes", "seeds")
ABS_FLOOR = {"slo_violation_pct": 1.0, "cost_usd": 1.0}
FRESH_DIR = os.environ.get("REPRO_BENCH_OUT", os.path.join("artifacts",
                                                           "bench"))


def _points(doc: Dict) -> Dict[str, Dict[str, float]]:
    return {name: p.get("total", {}) for name, p in
            doc.get("points", {}).items()}


def compare(baseline: Dict, fresh: Dict, threshold: float,
            metrics: Sequence[str]) -> List[Tuple[str, str, float, float]]:
    """Returns (point, metric, base, new) for every regression."""
    base_pts = _points(baseline)
    fresh_pts = _points(fresh)
    regressions = []
    for name in sorted(set(base_pts) & set(fresh_pts)):
        for metric in metrics:
            b = base_pts[name].get(metric)
            f = fresh_pts[name].get(metric)
            if b is None or f is None:
                continue
            # per-cause blame shares are percentages of a noisy total:
            # give them a 5-point absolute floor so a 0.2% -> 0.5%
            # share move doesn't flag as a 150% regression
            default_floor = 5.0 if metric.startswith("cause_") else 0.0
            floor = ABS_FLOOR.get(metric, default_floor)
            if f > b * (1.0 + threshold) + floor * threshold:
                regressions.append((name, metric, b, f))
    return regressions


def delta_table(baseline: Dict, fresh: Dict,
                metrics: Sequence[str]) -> List[str]:
    """One line per gated metric — mean baseline vs fresh over the
    shared points plus the worst single-point move — printed on every
    diff, regressing or not, so a passing run still shows its drift."""
    base_pts = _points(baseline)
    fresh_pts = _points(fresh)
    shared = sorted(set(base_pts) & set(fresh_pts))
    lines = [f"  {'metric':18s} {'base(mean)':>10s} {'fresh(mean)':>11s} "
             f"{'delta':>7s}  worst point"]
    for metric in metrics:
        pairs = [(base_pts[n].get(metric), fresh_pts[n].get(metric), n)
                 for n in shared]
        pairs = [(b, f, n) for b, f, n in pairs
                 if b is not None and f is not None]
        if not pairs:
            lines.append(f"  {metric:18s} {'-':>10s} {'-':>11s} {'-':>7s}  "
                         "(no shared points)")
            continue
        mb = sum(b for b, _, _ in pairs) / len(pairs)
        mf = sum(f for _, f, _ in pairs) / len(pairs)
        rel = (mf - mb) / max(abs(mb), 1e-9)
        wb, wf, wn = max(pairs, key=lambda p: (p[1] - p[0])
                         / max(abs(p[0]), 1e-9))
        wrel = (wf - wb) / max(abs(wb), 1e-9)
        lines.append(f"  {metric:18s} {mb:10.4g} {mf:11.4g} {rel:+7.1%}  "
                     f"{wn} ({wb:.4g} -> {wf:.4g}, {wrel:+.1%})")
    return lines


def check_pair(baseline_path: str, fresh_path: str,
               threshold: float) -> int:
    """Diff one baseline:fresh pair; returns 1 on regression else 0."""
    tag = os.path.basename(baseline_path)
    if not os.path.exists(baseline_path):
        print(f"[{tag}] no committed baseline at {baseline_path}; "
              "nothing to diff")
        return 0
    if not os.path.exists(fresh_path):
        print(f"[{tag}] no fresh result at {fresh_path}; "
              "run the benchmark first")
        return 0
    with open(baseline_path) as fh:
        baseline = json.load(fh)
    with open(fresh_path) as fh:
        fresh = json.load(fh)

    base_cfg = baseline.get("config", {})
    fresh_cfg = fresh.get("config", {})
    cfg_keys = baseline.get("config_keys", DEFAULT_CONFIG_KEYS)
    if any(base_cfg.get(k) != fresh_cfg.get(k) for k in cfg_keys):
        diffs = {k: (base_cfg.get(k), fresh_cfg.get(k)) for k in cfg_keys
                 if base_cfg.get(k) != fresh_cfg.get(k)}
        detail = "; ".join(f"{k}: baseline={b!r} fresh={f!r}"
                           for k, (b, f) in sorted(diffs.items()))
        print(f"[{tag}] sweep configs diverge on "
              f"{', '.join(sorted(diffs))} ({detail}); skipping the diff")
        return 0

    metrics = tuple(baseline.get("metrics", DEFAULT_METRICS))
    regressions = compare(baseline, fresh, threshold, metrics)
    shared = len(set(_points(baseline)) & set(_points(fresh)))
    for line in delta_table(baseline, fresh, metrics):
        print(line)
    if not regressions:
        print(f"[{tag}] OK: no >{threshold:.0%} regressions across "
              f"{shared} shared points ({', '.join(metrics)})")
        return 0
    print(f"[{tag}] REGRESSIONS (> {threshold:.0%} over baseline):")
    for name, metric, b, f in regressions:
        print(f"  {name}: {metric} {b:.4g} -> {f:.4g} "
              f"(+{(f - b) / max(abs(b), 1e-9):.0%})")
    return 1


def default_pairs(pattern: str) -> List[Tuple[str, str]]:
    """BENCH_<name>.json at the repo root -> artifacts/bench/<name>.json."""
    pairs = []
    for base in sorted(globlib.glob(pattern)):
        name = os.path.basename(base)
        if name.startswith("BENCH_") and name.endswith(".json"):
            stem = name[len("BENCH_"):-len(".json")]
        else:
            stem = os.path.splitext(name)[0]
        pairs.append((base, os.path.join(FRESH_DIR, f"{stem}.json")))
    return pairs


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", action="append", default=[],
                    metavar="BASELINE:FRESH",
                    help="baseline:fresh path pair; repeatable")
    ap.add_argument("--glob", default="BENCH_*.json",
                    help="discover baselines by glob when no --pair given")
    ap.add_argument("--baseline", default=None,
                    help="(legacy) single baseline path")
    ap.add_argument("--fresh", default=None,
                    help="(legacy) single fresh path")
    ap.add_argument("--threshold", type=float, default=0.10)
    args = ap.parse_args(argv)

    pairs: List[Tuple[str, str]] = []
    for spec in args.pair:
        parts = spec.split(":")
        if len(parts) != 2:
            ap.error(f"--pair expects BASELINE:FRESH, got {spec!r}")
        pairs.append((parts[0], parts[1]))
    if args.baseline or args.fresh:
        base = args.baseline or "BENCH_multitenant.json"
        fresh = args.fresh or os.path.join(FRESH_DIR, "multitenant.json")
        pairs.append((base, fresh))
    if not pairs:
        pairs = default_pairs(args.glob)
    if not pairs:
        print(f"no baselines match {args.glob!r}; nothing to diff")
        return 0

    rc = 0
    for baseline_path, fresh_path in pairs:
        rc |= check_pair(baseline_path, fresh_path, args.threshold)
    return rc


if __name__ == "__main__":
    sys.exit(main())
