"""Table 1 analog: few-shot prompting vs prompt tuning on the testbed
LLMs (the paper's GPT-3.5/GPT-4 columns are commercial APIs — out of
scope; the open-model columns are reproduced structurally).

Few-shot = k demonstration pairs concatenated in-context, no tuning.
Prompt tuning = the bank-selected prompt tuned briefly on the task.
Score = exact-match token accuracy on held-out samples (x100).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import fmt, make_ita_context, save_result, table


def _accuracy(model, params, prompt, batch) -> float:
    import jax.numpy as jnp

    logits, _ = model.forward(params, batch["tokens"],
                              prompt=None if prompt is None
                              else jnp.asarray(prompt))
    S = batch["tokens"].shape[1]
    pred = jnp.argmax(logits[:, -S:, :], axis=-1)
    mask = batch["mask"]
    hit = (pred == batch["labels"]) * mask
    return float(100.0 * hit.sum() / jnp.maximum(mask.sum(), 1.0))


def few_shot_batch(task, k: int, rng, batch=16):
    """Concatenate k demonstration pairs before the query (in-context)."""
    import numpy as np

    from repro.data.synthetic import sample_batch

    demos = sample_batch(task, rng, k)
    query = sample_batch(task, rng, batch)
    # prepend the same k demo sequences to every query row
    demo_flat = demos["tokens"].reshape(-1)
    tokens = np.concatenate(
        [np.tile(demo_flat, (batch, 1)), query["tokens"]], axis=1)
    pad = np.zeros((batch, demo_flat.size), np.float32)
    labels = np.concatenate(
        [np.tile(demos["labels"].reshape(-1), (batch, 1)),
         query["labels"]], axis=1)
    mask = np.concatenate([pad, query["mask"]], axis=1)
    return {"tokens": tokens.astype(np.int32),
            "labels": labels.astype(np.int32), "mask": mask}


def run(quick: bool = False) -> Dict:
    import jax.numpy as jnp

    from repro.data import LoaderConfig, TaskLoader, batch_to_jnp
    from repro.tuning import PromptTuner

    llms = ["gpt2-base"] if quick else ["gpt2-base", "gpt2-large",
                                        "vicuna-7b"]
    n_tasks = 3 if quick else 6
    out: Dict = {}
    for llm in llms:
        ctx = make_ita_context(llm)
        rng = np.random.default_rng(3)
        task_ids = rng.choice(len(ctx.pre.tasks), size=n_tasks,
                              replace=False)
        fs_scores, pt_scores = [], []
        for ti in task_ids:
            task = ctx.pre.tasks[int(ti)]
            loader = TaskLoader(task, LoaderConfig(batch_size=16))
            eval_b = batch_to_jnp(loader.eval_batch(32))
            # few-shot (4 demos, no tuning, no prompt)
            fsb = batch_to_jnp(few_shot_batch(task, 4,
                                              np.random.default_rng(9)))
            fs_scores.append(_accuracy(ctx.pre.model, ctx.pre.params, None,
                                       fsb))
            # prompt tuning from the bank pick (short budget)
            from repro.core.bank_builder import make_score_fn
            sc = make_score_fn(ctx.pre, task, ctx.tune_cfg)
            pick = ctx.bank.lookup(sc)
            tuner = PromptTuner(ctx.pre.model, ctx.tune_cfg)
            res = tuner.tune(ctx.pre.params, loader,
                             {"soft_prompt": jnp.asarray(pick.entry.prompt)},
                             target_loss=ctx.target_for(task),
                             max_iters=100 if quick else 200)
            pt_scores.append(_accuracy(ctx.pre.model, ctx.pre.params,
                                       res["prompt"]["soft_prompt"], eval_b))
        out[llm] = {
            "few_shot": float(np.mean(fs_scores)),
            "prompt_tuning": float(np.mean(pt_scores)),
            "improvement_x": float(np.mean(pt_scores)
                                   / max(np.mean(fs_scores), 1e-6)),
        }
    rows = [[llm, fmt(r["few_shot"], 1), fmt(r["prompt_tuning"], 1),
             fmt(r["improvement_x"], 1)] for llm, r in out.items()]
    print(table("Table 1 — few-shot vs prompt tuning (testbed; paper: "
                "2.2-5.4x on open LLMs)",
                ["llm", "few-shot", "prompt tuning", "x"], rows))
    save_result("table1", out)
    return out


if __name__ == "__main__":
    run()
