"""Table 8 + Fig 8a/b: Workload Scheduler ablations and the prompt- /
runtime-reusing feature analysis."""
from __future__ import annotations

from typing import Dict

from benchmarks.common import fmt, save_result, table
from repro.cluster import SimConfig, TraceConfig, clone_jobs, generate_trace, policies

ABLATIONS = {
    "full": {},
    "w/o warm allocator": {"use_warm_allocator": False},
    "w/o DelaySchedulable": {"use_delay": False},
    "w/o latency budget": {"use_latency_budget": False},
}

FEATURES = {
    "P.R.+R.R.": {},
    "w/o P.R.": {"use_bank": False},
    "w/o R.R.": {"use_warm": False},
    "w/o both": {"use_bank": False, "use_warm": False},
}


def _run(cfg_kw: Dict, S: float = 1.0, seeds: int = 3,
         minutes: int = 20) -> Dict:
    agg = {"slo_violation_pct": 0.0, "cost_usd": 0.0}
    for sd in range(seeds):
        jobs = generate_trace(TraceConfig(load="medium", slo_emergence=S,
                                          seed=sd, minutes=minutes))
        res = policies.build("prompttuner",
                          SimConfig(max_gpus=32, **cfg_kw)).run(
            clone_jobs(jobs)).summary()
        agg["slo_violation_pct"] += res["slo_violation_pct"] / seeds
        agg["cost_usd"] += res["cost_usd"] / seeds
    return agg


def run(quick: bool = False) -> Dict:
    seeds = 1 if quick else 3
    minutes = 10 if quick else 20
    out = {"table8": {}, "fig8ab": {}}
    for name, kw in ABLATIONS.items():
        out["table8"][name] = _run(kw, seeds=seeds, minutes=minutes)
    rows = [[n, fmt(r["slo_violation_pct"], 1), fmt(r["cost_usd"], 1)]
            for n, r in out["table8"].items()]
    print(table("Table 8 — scheduler ablations (medium load, S=1.0)",
                ["variant", "viol %", "cost $"], rows))

    for S in (0.5, 1.0, 1.5):
        out["fig8ab"][str(S)] = {
            name: _run(kw, S=S, seeds=seeds, minutes=minutes)
            for name, kw in FEATURES.items()
        }
    rows = []
    for S, r in out["fig8ab"].items():
        rows.append([S] + [fmt(r[n]["slo_violation_pct"], 1)
                           for n in FEATURES]
                    + [fmt(r[n]["cost_usd"], 0) for n in FEATURES])
    print(table("Fig 8a/b — prompt/runtime reusing (viol % | cost $)",
                ["S"] + [f"viol {n}" for n in FEATURES]
                + [f"$ {n}" for n in FEATURES], rows))
    save_result("ablation", out)
    return out


if __name__ == "__main__":
    run()
