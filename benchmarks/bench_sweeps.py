"""Fig 8c/d: reclaim-window size sweep and Prompt Bank size sweep.

The bank-size sweep grounds prompt quality in REAL lookups: the bank is
subsampled, the best found score per task is measured, and the ITA
degradation factor (relative to the full bank's pick) feeds the
simulator's ``bank_over_ideal`` spread.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import fmt, make_ita_context, save_result, table
from repro.cluster import SimConfig, TraceConfig, clone_jobs, generate_trace, policies


def window_sweep(windows=(15, 30, 60, 120, 240), seeds: int = 3,
                 minutes: int = 20) -> Dict:
    out = {}
    for w in windows:
        agg = {"slo_violation_pct": 0.0, "cost_usd": 0.0}
        for sd in range(seeds):
            jobs = generate_trace(TraceConfig(load="medium", seed=sd,
                                              minutes=minutes))
            r = policies.build("prompttuner",
                            SimConfig(max_gpus=32, reclaim_window=w)).run(
                clone_jobs(jobs)).summary()
            agg["slo_violation_pct"] += r["slo_violation_pct"] / seeds
            agg["cost_usd"] += r["cost_usd"] / seeds
        out[str(w)] = agg
    return out


def bank_size_quality(llm: str = "gpt2-base", sizes=(0.25, 0.5, 0.75, 1.0),
                      n_tasks: int = 6) -> Dict:
    """Relative score degradation of the two-layer pick as the bank
    shrinks (REAL lookups on the testbed)."""
    from repro.core.bank_builder import make_score_fn
    from repro.core.prompt_bank import PromptBank

    ctx = make_ita_context(llm)
    full = ctx.bank
    rng = np.random.default_rng(0)
    task_ids = rng.choice(len(ctx.pre.tasks), size=n_tasks, replace=False)
    entries = [e for e in full.entries if e.origin != "<evicted>"]
    # (bank-size sweep keeps all tasks' prompts: it measures capacity vs
    # selection quality, not transfer)
    out = {}
    for frac in sizes:
        n = max(int(len(entries) * frac), 4)
        sub = PromptBank(capacity=3000,
                         num_clusters=max(2, min(48, n // 4)))
        idx = rng.choice(len(entries), size=n, replace=False)
        sub.add_candidates([entries[i] for i in idx])
        sub.build()
        scores = []
        for ti in task_ids:
            sc = make_score_fn(ctx.pre, ctx.pre.tasks[int(ti)], ctx.tune_cfg)
            scores.append(sub.lookup(sc).score)
        out[str(frac)] = {"bank_size": n,
                          "mean_best_score": float(np.mean(scores))}
    return out


def bank_size_sim(quality: Dict, seeds: int = 3, minutes: int = 20) -> Dict:
    """Feed measured quality degradation into the simulator: a worse
    selected prompt widens bank_over_ideal (more iterations needed)."""
    import repro.cluster.trace as trace_mod

    base = quality["1.0"]["mean_best_score"]
    out = {}
    for frac, q in quality.items():
        # score -> iteration factor: loss gap shifts ITA multiplicatively;
        # clamp into the measured manual range
        degr = 1.0 + max(q["mean_best_score"] - base, 0.0) * 0.5
        cal = trace_mod.load_calibration()
        cal = {**cal, "bank_over_ideal": {
            "lo": cal["bank_over_ideal"]["lo"] * degr,
            "hi": cal["bank_over_ideal"]["hi"] * degr}}
        orig = trace_mod.load_calibration
        trace_mod.load_calibration = lambda c=cal: c
        try:
            agg = {"slo_violation_pct": 0.0, "cost_usd": 0.0}
            for sd in range(seeds):
                jobs = generate_trace(TraceConfig(load="medium", seed=sd,
                                                  minutes=minutes))
                r = policies.build("prompttuner",
                                SimConfig(max_gpus=32)).run(
                    clone_jobs(jobs)).summary()
                agg["slo_violation_pct"] += r["slo_violation_pct"] / seeds
                agg["cost_usd"] += r["cost_usd"] / seeds
            out[frac] = {**agg, "ita_degradation": degr,
                         "bank_size": q["bank_size"]}
        finally:
            trace_mod.load_calibration = orig
    return out


def run(quick: bool = False) -> Dict:
    seeds = 1 if quick else 3
    minutes = 10 if quick else 20
    out = {}
    out["fig8c_window"] = window_sweep(seeds=seeds, minutes=minutes)
    rows = [[w, fmt(r["slo_violation_pct"], 1), fmt(r["cost_usd"], 1)]
            for w, r in out["fig8c_window"].items()]
    print(table("Fig 8c — reclaim window sweep", ["window_s", "viol %",
                                                  "cost $"], rows))
    quality = bank_size_quality(n_tasks=3 if quick else 6)
    out["fig8d_quality"] = quality
    out["fig8d_sim"] = bank_size_sim(quality, seeds=seeds, minutes=minutes)
    rows = [[f, r["bank_size"], fmt(r["ita_degradation"], 3),
             fmt(r["slo_violation_pct"], 1), fmt(r["cost_usd"], 1)]
            for f, r in out["fig8d_sim"].items()]
    print(table("Fig 8d — bank size sweep",
                ["frac", "size", "ITA degr", "viol %", "cost $"], rows))
    save_result("sweeps", out)
    return out


if __name__ == "__main__":
    run()
