"""Prompt Bank (§4.3): a two-layer query engine over prompt candidates.

Layer 1 holds the K-medoid *representative prompts*; layer 2 the cluster
members. Clustering distance = cosine distance between LLM *activation
features* of each candidate (extracted once, offline). Lookup (Fig 5a)
computes Eqn-1 ``score`` for the K representatives, picks the best
cluster, then scores its members — ``K + C/K`` score evaluations instead
of ``C`` (optimal ``K = sqrt(C)`` -> ``2 sqrt(C)``). Insertion (Fig 5b)
routes the new candidate to the cluster whose medoid is nearest in
feature space (NO score evaluation), and replacement evicts the member
closest to its medoid (max diversity) once capacity is exceeded.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# K-medoid clustering (PAM-lite: alternate assign / medoid update)
# ---------------------------------------------------------------------------


def cosine_distance(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """a: (n, d); b: (m, d) -> (n, m) cosine distances in [0, 2]."""
    an = a / (np.linalg.norm(a, axis=-1, keepdims=True) + 1e-12)
    bn = b / (np.linalg.norm(b, axis=-1, keepdims=True) + 1e-12)
    return 1.0 - an @ bn.T


def k_medoids(
    features: np.ndarray, k: int, *, iters: int = 25, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (medoid_indices (k,), assignment (n,)). Cosine distance.

    §5.2: the paper found K-medoid over cosine converges where
    Manhattan/Euclidean do not; we implement the cosine variant."""
    n = features.shape[0]
    k = min(k, n)
    rng = np.random.default_rng(seed)
    D = cosine_distance(features, features)
    # k-means++-style seeding on the distance matrix
    medoids = [int(rng.integers(n))]
    for _ in range(k - 1):
        dmin = np.clip(D[:, medoids].min(axis=1), 0.0, None)
        if dmin.sum() <= 1e-12:      # all points coincide with a medoid
            medoids.append(int(rng.integers(n)))
            continue
        probs = dmin / dmin.sum()
        medoids.append(int(rng.choice(n, p=probs)))
    medoids = np.array(sorted(set(medoids)))
    while len(medoids) < k:  # de-dup fallback
        cand = int(rng.integers(n))
        if cand not in medoids:
            medoids = np.append(medoids, cand)
    for _ in range(iters):
        assign = np.argmin(D[:, medoids], axis=1)
        new_medoids = medoids.copy()
        for ci in range(len(medoids)):
            members = np.where(assign == ci)[0]
            if len(members) == 0:
                continue
            sub = D[np.ix_(members, members)]
            new_medoids[ci] = members[int(np.argmin(sub.sum(axis=1)))]
        if np.array_equal(new_medoids, medoids):
            break
        medoids = new_medoids
    assign = np.argmin(D[:, medoids], axis=1)
    return medoids, assign


# ---------------------------------------------------------------------------
# The bank
# ---------------------------------------------------------------------------


@dataclass
class PromptEntry:
    prompt: np.ndarray            # (P, d) soft prompt (or token ids for text)
    feature: np.ndarray           # (f,) activation feature
    origin: str = ""              # provenance (task it was optimized for)


@dataclass
class LookupResult:
    entry: PromptEntry
    score: float
    evaluations: int              # number of Eqn-1 evaluations performed
    latency_s: float
    cluster: int


class PromptBank:
    """Two-layer data structure with lookup / insert / replace (§4.3).

    ``score_fn(prompt) -> float`` is Eqn 1 evaluated by the caller (it owns
    the model + eval set); the bank is agnostic to how scores are computed,
    which also lets tests drive it with synthetic scorers.
    """

    def __init__(
        self,
        *,
        capacity: int = 3000,
        num_clusters: int = 50,
        seed: int = 0,
    ):
        self.capacity = capacity
        self.num_clusters = num_clusters
        self.seed = seed
        self.entries: List[PromptEntry] = []
        # two-layer structure
        self.medoid_ids: List[int] = []          # layer 1: entry index per cluster
        self.clusters: List[List[int]] = []      # layer 2: entry indices
        self._built = False

    # -- construction --------------------------------------------------------

    def add_candidates(self, entries: Sequence[PromptEntry]) -> None:
        self.entries.extend(entries)
        self._built = False

    def build(self) -> float:
        """(Re-)cluster all candidates. Returns build time in seconds."""
        t0 = time.time()
        if not self.entries:
            raise ValueError("empty bank")
        feats = np.stack([e.feature for e in self.entries])
        k = min(self.num_clusters, len(self.entries))
        medoids, assign = k_medoids(feats, k, seed=self.seed)
        self.medoid_ids = [int(m) for m in medoids]
        self.clusters = [
            [int(i) for i in np.where(assign == ci)[0]] for ci in range(len(medoids))
        ]
        self._built = True
        return time.time() - t0

    def __len__(self) -> int:
        return sum(1 for e in self.entries if e.origin != "<evicted>")

    # -- lookup (Fig 5a) ------------------------------------------------------

    def lookup(self, score_fn: Callable[[PromptEntry], float]) -> LookupResult:
        """Two-layer lookup: score K medoids, then members of the best
        cluster; K + C/K evaluations total."""
        assert self._built, "call build() first"
        t0 = time.time()
        evals = 0
        best_ci, best_medoid_score = 0, float("inf")
        for ci, mid in enumerate(self.medoid_ids):
            s = score_fn(self.entries[mid])
            evals += 1
            if s < best_medoid_score:
                best_medoid_score, best_ci = s, ci
        best_idx, best_score = self.medoid_ids[best_ci], best_medoid_score
        for idx in self.clusters[best_ci]:
            if idx == self.medoid_ids[best_ci]:
                continue
            if self.entries[idx].origin == "<evicted>":
                continue
            s = score_fn(self.entries[idx])
            evals += 1
            if s < best_score:
                best_score, best_idx = s, idx
        return LookupResult(
            entry=self.entries[best_idx],
            score=best_score,
            evaluations=evals,
            latency_s=time.time() - t0,
            cluster=best_ci,
        )

    def lookup_flat(self, score_fn) -> LookupResult:
        """Brute force over all C candidates (the K=1 baseline of Fig 10b)."""
        t0 = time.time()
        scores = [score_fn(e) for e in self.entries]
        i = int(np.argmin(scores))
        return LookupResult(
            entry=self.entries[i],
            score=float(scores[i]),
            evaluations=len(scores),
            latency_s=time.time() - t0,
            cluster=-1,
        )

    # -- insertion & replacement (Fig 5b) --------------------------------------

    def insert(self, entry: PromptEntry) -> Tuple[int, Optional[int]]:
        """Insert by feature similarity to medoids (no score evaluations).
        Returns (cluster_idx, evicted_entry_idx or None)."""
        assert self._built, "call build() first"
        med_feats = np.stack([self.entries[m].feature for m in self.medoid_ids])
        d = cosine_distance(entry.feature[None], med_feats)[0]
        ci = int(np.argmin(d))                                    # C_sim
        self.entries.append(entry)
        new_idx = len(self.entries) - 1
        self.clusters[ci].append(new_idx)
        evicted = None
        if len(self) > self.capacity:
            evicted = self._replace(ci)
        return ci, evicted

    def _replace(self, ci: int) -> int:
        """Evict the member of C_sim closest to its representative prompt
        (maximizing remaining diversity). The medoid itself is kept."""
        mid = self.medoid_ids[ci]
        members = [i for i in self.clusters[ci] if i != mid]
        if not members:
            return -1
        mfeat = self.entries[mid].feature[None]
        feats = np.stack([self.entries[i].feature for i in members])
        d = cosine_distance(feats, mfeat)[:, 0]
        victim = members[int(np.argmin(d))]
        self.clusters[ci].remove(victim)
        # tombstone: keep list indices stable, mark entry unusable
        self.entries[victim] = PromptEntry(
            prompt=np.zeros_like(self.entries[victim].prompt),
            feature=self.entries[victim].feature,
            origin="<evicted>",
        )
        return victim

    # -- stats ------------------------------------------------------------------

    def expected_evaluations(self) -> float:
        k = len(self.medoid_ids)
        c = len(self.entries)
        return k + c / max(k, 1)
