"""Job model (Table 3), LLM execution profiles, and the cost model.

Times are seconds (floats, absolute sim time). An LPT job's execution time
with ``a`` GPUs is

    T_exec(a) = iters * iter_time(a) + overheads

where ``iter_time(a) = t1 / r * (1 + comm_frac * (r - 1))`` with
``r = a / gpus_per_replica`` — near-linear scaling, communication is
0.4-0.5 % of step time (paper Fig 2a). Tensor-parallel models allocate in
replica units (paper §6.2: LLaMA-30B/Qwen7B-R1 use 4-GPU replicas).

Cost model (§6.1): AWS p4de.24xlarge — 8xA100-80GB at ~$40.97/h
=> $5.12 per GPU-hour for every *provisioned* (warm or fixed-cluster)
GPU-second, plus a small storage/communication charge per multi-GPU job
(the Memcached/ElastiCache channel).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, Optional

GPU_PRICE_PER_S = 40.97 / 8 / 3600.0        # $/GPU-second
STORAGE_PRICE_PER_JOB_S = 0.125 / 3600.0    # ElastiCache GB-hour sliver


@dataclass(frozen=True)
class LLMProfile:
    name: str
    iter_time_1replica: float      # seconds per LPT iteration on one replica
    cold_overhead: float           # container + runtime + weight load (s)
    warm_overhead: float           # connect instances / reuse runtime (s)
    gpus_per_replica: int = 1
    comm_frac: float = 0.005       # cross-GPU comm share per extra replica
    bank_lookup_s: float = 6.0     # Prompt Bank latency (Fig 10b: 5.3-9.2 s)


LLM_PROFILES: Dict[str, LLMProfile] = {
    "gpt2-base": LLMProfile("gpt2-base", 0.12, 12.0, 1.0, 1, bank_lookup_s=5.3),
    "gpt2-large": LLMProfile("gpt2-large", 0.30, 20.0, 1.5, 1, bank_lookup_s=6.1),
    "vicuna-7b": LLMProfile("vicuna-7b", 1.00, 45.0, 2.0, 1, bank_lookup_s=9.2),
    "llama-30b": LLMProfile("llama-30b", 2.50, 90.0, 3.0, 4, bank_lookup_s=12.0),
    "qwen7b-r1": LLMProfile("qwen7b-r1", 1.80, 60.0, 2.5, 4, bank_lookup_s=10.0),
}


class JobPhase(Enum):
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"


@dataclass(frozen=True)
class SLOClass:
    """A tenant-facing service class: how stringent the SLO is, what the
    tenant pays for it, and how the scheduler breaks admission ties.

    ``slo_multiplier`` scales the raw per-request SLO a tenant states
    (premium classes buy tighter deadlines than the trace's nominal
    duration-based SLO, best-effort classes relax them). ``price_tier``
    multiplies the base GPU price in the per-tenant ledger. ``priority``
    orders admission *between* classes (higher first); within a class
    the scheduler keeps its deadline order.
    """

    name: str = "standard"
    slo_multiplier: float = 1.0
    price_tier: float = 1.0
    priority: int = 0


DEFAULT_SLO_CLASS = SLOClass()

# A small catalogue of the classes the multi-tenant traces and
# benchmarks draw from; anything can construct ad-hoc classes too.
SLO_CLASSES: Dict[str, SLOClass] = {
    "premium": SLOClass("premium", slo_multiplier=0.75, price_tier=2.0,
                        priority=2),
    "standard": DEFAULT_SLO_CLASS,
    "best-effort": SLOClass("best-effort", slo_multiplier=1.5,
                            price_tier=0.5, priority=-1),
}

DEFAULT_TENANT = "default"


@dataclass
class Job:
    """One LPT request (Table 3)."""
    job_id: int
    llm: str
    submit_time: float
    slo: float                     # seconds from submit (deadline = submit+slo)
    iters_manual: int              # ITA with the user's manual initial prompt
    iters_bank: int                # ITA with the Prompt Bank's initial prompt
    max_iters: int = 10_000
    task_id: str = ""
    tenant: str = DEFAULT_TENANT
    slo_class: SLOClass = DEFAULT_SLO_CLASS
    # runtime state
    phase: JobPhase = JobPhase.PENDING
    start_time: Optional[float] = None
    finish_time: Optional[float] = None
    gpus: int = 0
    used_bank: bool = False
    init_overhead: float = 0.0     # allocation / instance-init share, set at start
    # fault-tolerance state (crash-aware recovery; see cluster/faults.py)
    iters_done: int = 0            # checkpointed progress surviving a crash
    restarts: int = 0              # times this job was orphaned and retried

    @property
    def deadline(self) -> float:
        return self.submit_time + self.slo

    def profile(self) -> LLMProfile:
        return LLM_PROFILES[self.llm]

    def iters(self, used_bank: bool) -> int:
        """Remaining iterations: the route's ITA minus checkpointed
        progress (``iters_done`` is 0 unless the job survived a crash)."""
        total = min(self.iters_bank if used_bank else self.iters_manual,
                    self.max_iters)
        return max(total - self.iters_done, 0)


def iter_time(profile: LLMProfile, gpus: int) -> float:
    replicas = max(gpus // profile.gpus_per_replica, 1)
    return (
        profile.iter_time_1replica / replicas
        * (1.0 + profile.comm_frac * (replicas - 1))
    )


def exec_time(
    job: Job, gpus: int, *, used_bank: bool, alloc_overhead: float
) -> float:
    """Upper-bound completion estimate (§4.4: max remaining iters x max
    per-iter time + allocation overhead [+ bank lookup])."""
    prof = job.profile()
    t = job.iters(used_bank) * iter_time(prof, gpus) + alloc_overhead
    if used_bank:
        t += prof.bank_lookup_s
    return t
