"""Assemble a Prompt Bank from real artifacts and provide the initial-prompt
selection strategies compared in the paper (§6.1, Fig 9):

  * ``score``     — the Prompt Bank's two-layer lookup with Eqn 1.
  * ``ideal``     — shortlist by score, then pick best by *measured ITA*
                    (paper: computationally infeasible online; upper bound).
  * ``induction`` — automatic prompt generation by the LLM itself [88].
                    Our testbed analog: the model's own embedding of a
                    generic instruction (mean of related task prompts +
                    heavy noise, scaled by model capability) — it works
                    for simple tasks, degrades for weak models, mirroring
                    the paper's observation.
  * ``manual``    — a user-provided random prompt (current practice).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TuneConfig
from repro.core.prompt_bank import PromptBank, PromptEntry
from repro.data import LoaderConfig, TaskLoader, TaskSpec, batch_to_jnp
from repro.models import Model
from repro.train.pretrain import PretrainResult
from repro.tuning import PromptTuner, activation_features


def build_bank_from_pretrain(
    pre: PretrainResult,
    *,
    variants_per_prompt: int = 8,
    noise_scales: Sequence[float] = (0.0, 0.05, 0.15, 0.3),
    num_clusters: int = 0,
    capacity: int = 3000,
    seed: int = 0,
) -> PromptBank:
    """Candidates = per-task optimized prompts + jittered variants (the
    public-prompt corpus analog: many prompts of varying quality/tasks).
    Features are REAL model activations."""
    rng = np.random.default_rng(seed)
    entries: List[PromptEntry] = []
    feats_batch: List[np.ndarray] = []
    prompts: List[np.ndarray] = []
    origins: List[str] = []
    for task_id, prompt in pre.task_prompts.items():
        for v in range(variants_per_prompt):
            scale = noise_scales[v % len(noise_scales)]
            noise = rng.normal(0, scale * (np.abs(prompt).mean() + 1e-6),
                               size=prompt.shape)
            prompts.append((prompt + noise).astype(np.float32))
            origins.append(f"{task_id}/v{v}")
    # batch feature extraction (one forward for all candidates)
    stacked = jnp.asarray(np.stack(prompts))
    feats = activation_features(pre.model, pre.params, stacked)
    feats = np.atleast_2d(np.asarray(feats))
    for p, o, f in zip(prompts, origins, feats):
        entries.append(PromptEntry(prompt=p, feature=f, origin=o))
    # cluster count ~ distinct task groups beats sqrt(C) here
    # (Fig 10b sweep: see bench_bank); paper uses K=50 at C~3000
    k = num_clusters or max(2, min(48, len(entries) // 4))
    bank = PromptBank(capacity=capacity, num_clusters=k, seed=seed)
    bank.add_candidates(entries)
    bank.build()
    return bank


@dataclass
class ScoreContext:
    """Binds Eqn-1 scoring to (model, task eval set)."""
    tuner: PromptTuner
    params: Dict
    eval_batch: Dict

    def __call__(self, entry: PromptEntry) -> float:
        pp = {"soft_prompt": jnp.asarray(entry.prompt)}
        return self.tuner.score(pp, self.params, self.eval_batch)


def make_score_fn(pre: PretrainResult, task: TaskSpec, tune_cfg: TuneConfig,
                  loader: Optional[TaskLoader] = None) -> ScoreContext:
    loader = loader or TaskLoader(task, LoaderConfig(batch_size=tune_cfg.batch_size))
    tuner = PromptTuner(pre.model, tune_cfg)
    return ScoreContext(tuner, pre.params, loader.eval_batch(tune_cfg.eval_samples))


# ---------------------------------------------------------------------------
# Selection strategies
# ---------------------------------------------------------------------------


def select_score(bank: PromptBank, score_ctx: ScoreContext):
    """The Prompt Bank two-layer lookup."""
    return bank.lookup(score_ctx)


def select_ideal(
    bank: PromptBank,
    score_ctx: ScoreContext,
    measure_ita,
    shortlist: int = 20,
):
    """Paper's Ideal baseline: score-shortlist ``shortlist`` prompts then
    pick the one with best measured ITA (infeasible online)."""
    scored = []
    for e in bank.entries:
        if e.origin == "<evicted>":
            continue
        scored.append((score_ctx(e), e))
    scored.sort(key=lambda t: t[0])
    best_entry, best_ita = None, float("inf")
    for s, e in scored[:shortlist]:
        ita = measure_ita(e.prompt)
        if ita < best_ita:
            best_ita, best_entry = ita, e
    return best_entry, best_ita


def select_induction(
    pre: PretrainResult, task: TaskSpec, *, capability: float = 0.5, seed: int = 0
) -> np.ndarray:
    """Induction initialization [88]: the LLM generates its own initial
    prompt from demonstrations. Testbed analog: an imperfect recall of the
    family's optimized prompts — fidelity scales with model capability
    (bigger testbed LLM => better generated prompt), reproducing the
    paper's finding that induction relies on strong LLMs."""
    rng = np.random.default_rng(seed)
    related = [p for tid, p in pre.task_prompts.items()
               if tid.split(":")[0] == task.family]
    base = np.mean(related, axis=0) if related else list(pre.task_prompts.values())[0]
    noise_scale = (1.0 - capability) * 2.0 * (np.abs(base).mean() + 1e-6)
    return (base * capability + rng.normal(0, noise_scale, base.shape)).astype(
        np.float32
    )


def select_manual(pre: PretrainResult, seed: int = 0) -> np.ndarray:
    """Manual initialization: a generic, uninformed prompt."""
    rng = np.random.default_rng(seed)
    d = pre.model.cfg.d_model
    P = next(iter(pre.task_prompts.values())).shape[0]
    return (rng.normal(0, 0.5 / np.sqrt(d), (P, d))).astype(np.float32)


def measure_ita(
    pre: PretrainResult,
    task: TaskSpec,
    prompt: np.ndarray,
    tune_cfg: TuneConfig,
    *,
    target_loss: float,
    max_iters: int = 400,
) -> Tuple[int, bool]:
    """Iterations-To-Accuracy: REAL tuning run until eval loss target."""
    loader = TaskLoader(task, LoaderConfig(batch_size=tune_cfg.batch_size))
    tuner = PromptTuner(pre.model, tune_cfg)
    res = tuner.tune(
        pre.params, loader, {"soft_prompt": jnp.asarray(prompt)},
        target_loss=target_loss, max_iters=max_iters,
    )
    return res["iters"], res["reached"]
