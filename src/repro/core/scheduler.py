"""Deprecated shim for the PromptTuner Workload Scheduler (§4.4).

Algorithms 1 & 2 now live in
:mod:`repro.cluster.policies.prompttuner` as
:class:`~repro.cluster.policies.PromptTunerPolicy`, running over the
pure event engine in :mod:`repro.cluster.engine`. ``PromptTunerSim``
stays importable as a one-line policy wrapper; prefer::

    from repro.cluster import policies
    engine = policies.build("prompttuner", cfg)

or the service front door :class:`repro.api.PromptTunerService`.
"""
from __future__ import annotations

from repro.cluster.engine import ClusterEngine, SimConfig
from repro.cluster.policies.prompttuner import PromptTunerPolicy


class PromptTunerSim(ClusterEngine):
    """Deprecated: use ``policies.build('prompttuner', cfg)``."""

    def __init__(self, cfg: SimConfig):
        super().__init__(cfg, PromptTunerPolicy(cfg))
