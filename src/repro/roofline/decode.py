"""Analytic HBM-traffic model for one decode-step of attention.

Decode attention is memory-bound: one query token, a long KV cache, and
arithmetic intensity of ~1 FLOP/byte — so per-step latency is traffic /
HBM_BW, and a kernel's merit is how close its traffic sits to the floor
of reading the cache exactly once. These terms price both paths:

``naive``  — the unfused XLA decode the models fall back to: the (H, L)
  score tensor round-trips HBM between the QK matmul, the softmax, and
  the PV matmul (write S, read S, write P, read P — f32), on top of the
  cache read. For MLA the absorbed latent cache is read TWICE (once for
  scores, once as V).

``fused``  — the split-KV Pallas kernels (``flash_decode`` /
  ``mla_decode``): the cache is read once, scores live in VMEM only,
  and the extra traffic is the per-partition partials (o_part + lse,
  written once by the kernel, read once by the LSE combine).

Both include the q/output vectors, which are negligible at any real L.
The functions are pure arithmetic (no jax) so benchmarks and tests can
call them without a device; ``roofline_terms`` turns bytes into seconds.
"""
from __future__ import annotations

from typing import Dict

F32 = 4


def gqa_decode_hbm_bytes(*, B: int, H: int, Hkv: int, hd: int, L: int,
                         splits: int = 8, dtype_bytes: int = 2) -> Dict:
    """One GQA decode step: q (B,H,hd) against a (B,Hkv,L,hd) K/V cache."""
    kv = 2 * B * Hkv * L * hd * dtype_bytes          # read K and V once
    qo = 2 * B * H * hd * dtype_bytes                # q in, out vector out
    scores_rt = 4 * B * H * L * F32                  # S w+r, P w+r (naive)
    partials = splits * B * H * (hd + 1) * F32 * 2   # o_part+lse, w then r
    naive = kv + qo + scores_rt
    fused = kv + qo + partials
    return {
        "naive_bytes": float(naive),
        "fused_bytes": float(fused),
        "floor_bytes": float(kv + qo),               # cache-once lower bound
        "reduction_x": naive / fused,
        "flops": 4.0 * B * H * L * hd,               # QK^T + PV
    }


def mla_decode_hbm_bytes(*, B: int, H: int, r: int, rd: int, L: int,
                         splits: int = 8, dtype_bytes: int = 2) -> Dict:
    """One absorbed-MLA decode step: q_lat (B,H,r) + q_pe (B,H,rd)
    against the latent cache ckv (B,L,r) + kpe (B,L,rd)."""
    ckv = B * L * r * dtype_bytes
    kpe = B * L * rd * dtype_bytes
    qo = 2 * B * H * (r + rd) * F32                  # absorbed q in, latent out
    scores_rt = 4 * B * H * L * F32                  # S w+r, P w+r (naive)
    partials = splits * B * H * (r + 1) * F32 * 2    # o_part+lse, w then r
    naive = 2 * ckv + kpe + qo + scores_rt           # ckv read for S and as V
    fused = ckv + kpe + qo + partials                # single latent-cache pass
    return {
        "naive_bytes": float(naive),
        "fused_bytes": float(fused),
        "floor_bytes": float(ckv + kpe + qo),
        "reduction_x": naive / fused,
        "flops": 2.0 * B * H * L * (r + rd) + 2.0 * B * H * L * r,
    }
