from repro.roofline.extract import (
    HBM_BW,
    LINK_BW,
    PEAK_FLOPS,
    active_params,
    collective_bytes_from_hlo,
    cost_summary,
    memory_summary,
    model_flops,
    roofline_terms,
)

__all__ = [
    "HBM_BW",
    "LINK_BW",
    "PEAK_FLOPS",
    "active_params",
    "collective_bytes_from_hlo",
    "cost_summary",
    "memory_summary",
    "model_flops",
    "roofline_terms",
]
