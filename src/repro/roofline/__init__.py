from repro.roofline.decode import (
    gqa_decode_hbm_bytes,
    mla_decode_hbm_bytes,
)
from repro.roofline.extract import (
    HBM_BW,
    LINK_BW,
    PEAK_FLOPS,
    active_params,
    collective_bytes_from_hlo,
    cost_summary,
    memory_summary,
    model_flops,
    roofline_terms,
)

__all__ = [
    "HBM_BW",
    "LINK_BW",
    "PEAK_FLOPS",
    "active_params",
    "collective_bytes_from_hlo",
    "cost_summary",
    "gqa_decode_hbm_bytes",
    "memory_summary",
    "mla_decode_hbm_bytes",
    "model_flops",
    "roofline_terms",
]
