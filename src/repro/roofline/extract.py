"""Roofline-term extraction from compiled dry-run artifacts (deliverable g).

Three terms, per (arch x shape x mesh), in SECONDS:

    compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
    memory     = HLO_bytes / (chips * HBM_BW)
    collective = collective_bytes / (chips * LINK_BW)

``cost_analysis()`` provides HLO_FLOPs and bytes-accessed. Collective
bytes are NOT in cost_analysis: we parse the compiled HLO text and sum
the operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional

# TPU v5e per-chip constants (task spec)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

# matches e.g. f32[16,512,6272]{2,1,0} or bf16[8]
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, Any]:
    """Sum output-shape bytes of every collective op in the HLO.

    HLO line shape:  ``%name = f32[...] all-reduce(...), replica_groups=...``
    The lhs type is the op's output; for all-gather/all-reduce it equals
    the full communicated payload (post-gather / reduced tensor), which is
    the standard proxy for bytes moved per participant group.
    """
    per_op: Dict[str, int] = {k: 0 for k in _COLLECTIVE_OPS}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        # "%x = TYPE op-name(" — find which collective this line is
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        # strip fusion/custom-call wrappers: only direct collectives count
        for coll in _COLLECTIVE_OPS:
            if op == coll or op.startswith(coll + "-start"):
                b = _shape_bytes(type_str)
                per_op[coll] += b
                counts[coll] += 1
                break
    total = sum(per_op.values())
    return {
        "total_bytes": total,
        "per_op_bytes": per_op,
        "per_op_counts": counts,
    }


def cost_summary(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    out = {}
    for k in ("flops", "bytes accessed", "optimal_seconds",
              "utilization operand 0 {}", "transcendentals"):
        if k in ca:
            out[k.replace(" ", "_")] = float(ca[k])
    # keep all bytes-accessed breakdowns
    for k, v in ca.items():
        if k.startswith("bytes accessed"):
            out[k.replace(" ", "_")] = float(v)
    return out


def memory_summary(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out: Dict[str, float] = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    # per-device views (args/outputs are given for the whole program on
    # host-platform backends; divide by device count where meaningful)
    return out


def roofline_terms(
    flops: float,
    bytes_accessed: float,
    collective_bytes: float,
    *,
    n_devices: int = 1,
) -> Dict[str, float]:
    """The three terms in seconds + the dominant bottleneck.

    XLA's SPMD pipeline compiles ONE per-device program, so
    ``cost_analysis()`` flops/bytes and the HLO collective payloads are
    already PER-DEVICE quantities (verified against 6·N·D/chips for the
    dense archs). ``n_devices`` is therefore 1 unless the caller passes
    whole-program numbers."""
    t_comp = flops / (n_devices * PEAK_FLOPS)
    t_mem = bytes_accessed / (n_devices * HBM_BW)
    t_coll = collective_bytes / (n_devices * LINK_BW)
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant.replace("_s", "")
    terms["bound_s"] = max(t_comp, t_mem, t_coll)
    return terms


def model_flops(cfg, shape, *, backward: bool = False) -> float:
    """MODEL_FLOPS = 6·N·D for training (2·N·D forward-only), with N =
    active parameter count (MoE: only routed-active + shared experts)."""
    n = active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if backward else 2.0
    return mult * n * tokens


def active_params(cfg) -> float:
    """Active (per-token) parameter count, excluding embeddings."""
    d = cfg.d_model
    L = cfg.num_layers
    hd = cfg.resolved_head_dim()
    H, Hkv = cfg.num_heads, cfg.kv_heads()
    per_layer = 0.0
    if cfg.attention == "mla" and cfg.mla is not None:
        m = cfg.mla
        qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
        per_layer += d * m.q_lora_rank + m.q_lora_rank * H * qk_hd
        per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
        per_layer += m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
        per_layer += H * m.v_head_dim * d
    elif cfg.attention == "gqa":
        per_layer += d * H * hd + 2 * d * Hkv * hd + H * hd * d
    ffn_mult = 3 if cfg.activation == "swiglu" else 2
    if cfg.moe is not None:
        m = cfg.moe
        active_experts = m.top_k + m.num_shared_experts
        per_layer += ffn_mult * d * m.d_ff_expert * active_experts
        per_layer += d * m.num_experts            # router
    elif cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
        per_layer += 4 * d * d + ffn_mult * d * cfg.d_ff
    elif cfg.ssm is not None:
        di = cfg.ssm.expand * d
        per_layer += 2 * d * di + di * d
    else:
        per_layer += ffn_mult * d * cfg.d_ff
    total = per_layer * L
    if cfg.encdec is not None:
        total += cfg.encdec.num_encoder_layers * (
            d * H * hd + 2 * d * Hkv * hd + H * hd * d + ffn_mult * d * cfg.d_ff
        )
    return total
