"""Trip-count-aware HLO cost analysis (roofline source of truth).

``compiled.cost_analysis()`` counts each ``while`` body ONCE, but every
production model here runs layers / microbatches / KV blocks / CE chunks
under ``jax.lax.scan`` — so raw cost_analysis under-reports FLOPs,
bytes, and (via text parsing) collective payloads by 1-2 orders of
magnitude. Fortunately the compiled HLO records
``backend_config={"known_trip_count":{"n":...}}`` on every while op.

This module parses the post-optimization HLO text into its computation
graph and accumulates, bottom-up with trip-count multipliers:

  * ``flops``            — 2 * prod(dot output dims) * contracted size,
                           for every dot (einsum/matmul); elementwise and
                           reduce flops are ignored (matmul-dominated
                           workloads; transcendentals counted separately).
  * ``bytes``            — fusion-granularity HBM traffic proxy: operand
                           + output bytes of every top-level instruction
                           (instructions *inside* fused computations are
                           VMEM/register-internal and not counted).
  * ``collective_bytes`` — output payload of all-gather / all-reduce /
                           reduce-scatter / all-to-all / collective-
                           permute, by op kind.

All quantities are PER-DEVICE (the SPMD partitioner emits one per-device
program).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_LHS_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"\s*([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLEE_RE = {
    "body": re.compile(r"body=%?([\w.\-]+)"),
    "calls": re.compile(r"calls=%?([\w.\-]+)"),
    "to_apply": re.compile(r"to_apply=%?([\w.\-]+)"),
    "condition": re.compile(r"condition=%?([\w.\-]+)"),
}
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that move no real HBM bytes
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
}


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str
    is_root: bool = False


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # instr -> type str


def _split_instr(rhs: str) -> Optional[Tuple[str, str, str]]:
    """rhs after '=': '<type> <op>(<rest>' -> (type, op, rest).

    The type is either one token ('f32[16,3584]{1,0}') or a parenthesized
    tuple of shapes (which never nests parens)."""
    rhs = rhs.lstrip()
    if rhs.startswith("("):
        close = rhs.find(")")
        if close < 0:
            return None
        type_str, tail = rhs[: close + 1], rhs[close + 1:]
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str, tail = rhs[:sp], rhs[sp:]
    m = _OP_RE.match(tail)
    if not m:
        return None
    return type_str, m.group(1), m.group(2)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = Computation(m.group(1))
            continue
        s = line.rstrip()
        if s.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _LHS_RE.match(s)
        if not m:
            continue
        root, name, rhs = m.groups()
        parts = _split_instr(rhs)
        if parts is None:
            continue
        type_str, op, rest = parts
        cur.instrs.append(Instr(name, type_str.strip(), op, rest,
                                is_root=bool(root)))
        cur.shapes[name] = type_str.strip()
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _dot_flops(ins: Instr, comp: Computation) -> float:
    """2 * prod(out dims) * contracted-size."""
    out_dims = _shape_dims(ins.type_str)
    if not out_dims:
        return 0.0
    out_n = 1
    for d in out_dims[0][1]:
        out_n *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    if not m:
        return 2.0 * out_n          # degenerate
    cdims = [int(x) for x in m.group(1).split(",") if x]
    ops = _OPERAND_RE.findall(ins.rest.split(")")[0])
    if not ops:
        return 0.0
    lhs_type = comp.shapes.get(ops[0], "")
    lhs_dims = _shape_dims(lhs_type)
    if not lhs_dims:
        return 0.0
    csize = 1
    for cd in cdims:
        dims = lhs_dims[0][1]
        if cd < len(dims):
            csize *= dims[cd]
    return 2.0 * out_n * csize


def _operand_shapes_named(ins: Instr, comp: Computation
                          ) -> List[Tuple[str, str]]:
    # operand list ends at the first close paren at depth 0
    depth = 0
    end = len(ins.rest)
    for i, ch in enumerate(ins.rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                end = i
                break
            depth -= 1
    out = []
    for opname in _OPERAND_RE.findall(ins.rest[:end]):
        t = comp.shapes.get(opname)
        if t:
            out.append((opname, t))
    return out


def _operand_shapes(ins: Instr, comp: Computation) -> List[str]:
    return [t for _, t in _operand_shapes_named(ins, comp)]


_WINDOW_OPS = {"dynamic-slice", "slice", "gather"}


def _is_broadcast_only_fusion(ins: Instr, comps) -> bool:
    """Scan output-buffer inits get sunk into while bodies on the CPU
    backend; XLA aliases them on TPU, so they're charged once."""
    if ins.op != "fusion" or comps is None:
        return False
    m = _CALLEE_RE["calls"].search(ins.rest)
    callee = comps.get(m.group(1)) if m else None
    if callee is None:
        return False
    return {c.op for c in callee.instrs} <= {
        "parameter", "constant", "broadcast", "bitcast", "iota"}


def _fusion_bytes(ins: Instr, comp: Computation,
                  comps: Optional[Dict[str, "Computation"]]) -> float:
    """Traffic of a fusion, window-aware.

    * An operand consumed ONLY through slice/dynamic-slice/gather inside
      the fused computation reads the window, not the resident buffer.
    * An operand that is only the in-place TARGET of dynamic-update-slice
      reads nothing (the untouched cells are never loaded on TPU).
    * If the fusion's root is a DUS (or a tuple of them) the write is the
      update window, not the whole aliased buffer — the dominant case for
      decode KV-cache updates (measured: 275 GB/step phantom traffic on
      phi3 decode_32k from whole-cache charges).
    """
    operands = _operand_shapes_named(ins, comp)
    full = _shape_bytes(ins.type_str) + sum(_shape_bytes(t)
                                            for _, t in operands)
    if comps is None:
        return full
    m = _CALLEE_RE["calls"].search(ins.rest)
    callee = comps.get(m.group(1)) if m else None
    if callee is None:
        return full
    callee_ops = {c.op for c in callee.instrs}
    if callee_ops <= {"parameter", "constant", "convert", "bitcast",
                      "copy", "dynamic-update-slice"}:
        # cache-update fusion (decode hot path): the CPU backend wraps the
        # DUS in f32 converts of the WHOLE buffer; a TPU reads+writes the
        # update window only
        w = 0.0
        for cins in callee.instrs:
            if cins.op == "dynamic-update-slice":
                ops_used = _OPERAND_RE.findall(cins.rest.split("),")[0])
                if len(ops_used) > 1:
                    w += _shape_bytes(callee.shapes.get(ops_used[1], ""))
        if w:
            return 2.0 * w
    params = {}
    for cins in callee.instrs:
        if cins.op == "parameter":
            pm = re.match(r"(\d+)", cins.rest)
            if pm:
                params[int(pm.group(1))] = cins.name
    # operand side
    total = 0.0
    for i, (_, type_str) in enumerate(operands):
        pname = params.get(i)
        if pname is None:
            total += _shape_bytes(type_str)
            continue
        window_bytes = 0.0
        windowed = True
        for cins in callee.instrs:
            ops_used = _OPERAND_RE.findall(cins.rest.split("),")[0])
            if pname not in ops_used:
                continue
            if cins.op in _WINDOW_OPS and ops_used and ops_used[0] == pname:
                window_bytes += _shape_bytes(cins.type_str)
            elif (cins.op == "dynamic-update-slice" and ops_used
                  and ops_used[0] == pname):
                window_bytes += 0.0          # in-place target: no read
            else:
                windowed = False
                break
        total += window_bytes if windowed else _shape_bytes(type_str)
    # output side: root DUS writes only the update window
    root = next((c for c in callee.instrs if c.is_root), None)
    out_b = _shape_bytes(ins.type_str)
    if root is not None:
        def dus_window(instr):
            ops_used = _OPERAND_RE.findall(instr.rest.split("),")[0])
            if len(ops_used) > 1:
                return _shape_bytes(callee.shapes.get(ops_used[1], ""))
            return _shape_bytes(instr.type_str)
        if root.op == "dynamic-update-slice":
            out_b = dus_window(root)
        elif root.op == "tuple":
            ops_used = _OPERAND_RE.findall(root.rest.split(")")[0])
            parts = 0.0
            for name_ in ops_used:
                producer = next((c for c in callee.instrs
                                 if c.name == name_), None)
                if producer is not None and producer.op == \
                        "dynamic-update-slice":
                    parts += dus_window(producer)
                else:
                    parts += _shape_bytes(callee.shapes.get(name_, ""))
            out_b = parts
    return total + out_b


def _instr_bytes(ins: Instr, comp: Computation,
                 comps: Optional[Dict[str, "Computation"]] = None) -> float:
    """Fusion-granularity HBM-traffic proxy with op-specific rules so that
    windowed reads of big buffers (slice / gather / DUS) count the moved
    bytes, not the whole resident operand."""
    op = ins.op
    out_b = _shape_bytes(ins.type_str)
    operands = _operand_shapes(ins, comp)
    if op in ("dynamic-slice", "slice", "gather", "broadcast", "iota",
              "reshape"):
        return out_b                          # reads ~= output size
    if op == "dynamic-update-slice":
        # in-place update: read+write of the update window only
        upd = _shape_bytes(operands[1]) if len(operands) > 1 else out_b
        return 2.0 * upd
    if op == "scatter":
        upd = _shape_bytes(operands[-1]) if operands else 0
        return out_b + upd
    if op == "fusion":
        return _fusion_bytes(ins, comp, comps)
    return out_b + sum(_shape_bytes(t) for t in operands)


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    bytes_hoistable: float = 0.0     # buffer inits XLA aliases/hoists
    transcendentals: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        # hoistable inits are paid once regardless of trip count
        self.bytes_hoistable += other.bytes_hoistable
        self.transcendentals += other.transcendentals * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + v * mult


def _convert_only_fusions(comps: Dict[str, Computation]) -> set:
    """Fusions whose callee only converts dtypes (bf16<->f32). The CPU
    host backend emulates bf16 in f32 and materializes these conversions;
    a real TPU computes bf16 natively, so their traffic is excluded from
    the roofline memory term (measured: 16.5 TB phantom traffic on
    phi3-14B train_4k — weight converts per microbatch x layer)."""
    out = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op != "fusion":
                continue
            m = _CALLEE_RE["calls"].search(ins.rest)
            if m and m.group(1) in comps:
                ops = {i.op for i in comps[m.group(1)].instrs}
                if ops <= {"parameter", "convert", "bitcast", "copy"}:
                    out.add(ins.name)
    return out


def analyze_hlo(text: str) -> Dict[str, Any]:
    """Per-device {flops, bytes, collective bytes by op, counts}."""
    comps = parse_hlo(text)
    # entry = computation named like ENTRY (last in file is typical for
    # HloModule dumps; detect by 'ENTRY' keyword occurrence)
    entry_name = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                entry_name = m.group(1)
    memo: Dict[str, Costs] = {}
    visiting: set = set()
    convert_only = _convert_only_fusions(comps)

    def total(name: str, inside_fusion: bool) -> Costs:
        key = name + ("/f" if inside_fusion else "")
        if key in memo:
            return memo[key]
        if name in visiting or name not in comps:
            return Costs()
        visiting.add(name)
        comp = comps[name]
        c = Costs()
        for ins in comp.instrs:
            op = ins.op
            if op == "dot":
                c.flops += _dot_flops(ins, comp)
            elif op in ("exponential", "log", "tanh", "rsqrt", "sqrt",
                        "power", "sine", "cosine", "logistic"):
                dims = _shape_dims(ins.type_str)
                n = 1
                for d in (dims[0][1] if dims else []):
                    n *= d
                c.transcendentals += n
            is_coll = None
            for coll in COLLECTIVES:
                if op == coll or op == coll + "-start":
                    is_coll = coll
                    break
            if is_coll:
                b = _shape_bytes(ins.type_str)
                c.coll[is_coll] = c.coll.get(is_coll, 0.0) + b
                c.coll_counts[is_coll] = c.coll_counts.get(is_coll, 0.0) + 1
            # bytes: top-level instructions only; dtype-convert fusions
            # are CPU-backend artifacts (see _convert_only_fusions)
            if not inside_fusion and op not in _FREE_OPS:
                if (not op.endswith("-done") and op != "while"
                        and ins.name not in convert_only):
                    b = _instr_bytes(ins, comp, comps)
                    if _is_broadcast_only_fusion(ins, comps):
                        c.bytes_hoistable += b
                    else:
                        c.bytes += b
            # recurse into callees
            if op == "while":
                mb = _CALLEE_RE["body"].search(ins.rest)
                mc = _CALLEE_RE["condition"].search(ins.rest)
                mt = _TRIP_RE.search(ins.rest)
                trips = float(mt.group(1)) if mt else 1.0
                if mb:
                    c.add(total(mb.group(1), inside_fusion), trips)
                if mc:
                    c.add(total(mc.group(1), inside_fusion), trips)
            elif op == "fusion":
                m = _CALLEE_RE["calls"].search(ins.rest)
                if m:
                    c.add(total(m.group(1), True), 1.0)
            elif op in ("call", "custom-call", "reduce", "reduce-window",
                        "scatter", "select-and-scatter", "sort", "map",
                        "async-start"):
                m = _CALLEE_RE["to_apply"].search(ins.rest) or \
                    _CALLEE_RE["calls"].search(ins.rest)
                if m and op in ("call", "custom-call", "async-start"):
                    c.add(total(m.group(1), inside_fusion), 1.0)
                # reduce/map bodies: scalar computations, negligible
            elif op == "conditional":
                for m in re.finditer(r"%([\w.\-]+)", ins.rest):
                    if m.group(1) in comps:
                        c.add(total(m.group(1), inside_fusion), 1.0)
                        break
        visiting.discard(name)
        memo[key] = c
        return c

    if entry_name is None:
        raise ValueError("no ENTRY computation found in HLO text")
    c = total(entry_name, False)
    return {
        "flops": c.flops,
        "bytes": c.bytes + c.bytes_hoistable,
        "transcendentals": c.transcendentals,
        "collectives": {
            "total_bytes": sum(c.coll.values()),
            "per_op_bytes": c.coll,
            "per_op_counts": c.coll_counts,
        },
    }


def top_contributors(text: str, kind: str = "bytes", n: int = 20):
    """Profiler view over the dry-run HLO: the n largest contributors to
    the memory term (kind='bytes') or collective term (kind='collective'),
    each as (total_bytes, multiplier, op, output_type, metadata_op_name).
    This is the 'profile' the perf loop iterates on (no real hardware)."""
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                entry = m.group(1)
    rows = []
    convert_only = _convert_only_fusions(comps)

    def meta(ins: Instr) -> str:
        m = re.search(r'op_name="([^"]+)"', ins.rest)
        return m.group(1)[-90:] if m else ""

    def walk(name: str, mult: float, inside_fusion: bool, depth: int = 0):
        if name not in comps or depth > 60:
            return
        comp = comps[name]
        for ins in comp.instrs:
            op = ins.op
            if kind == "bytes":
                if (not inside_fusion and op not in _FREE_OPS
                        and not op.endswith("-done") and op != "while"
                        and ins.name not in convert_only):
                    b = _instr_bytes(ins, comp, comps) * mult
                    if b > 0:
                        rows.append((b, mult, op, ins.type_str[:70],
                                     meta(ins)))
            else:
                for coll in COLLECTIVES:
                    if op == coll or op == coll + "-start":
                        rows.append((_shape_bytes(ins.type_str) * mult,
                                     mult, coll, ins.type_str[:70],
                                     meta(ins)))
            if op == "while":
                mb = _CALLEE_RE["body"].search(ins.rest)
                mc = _CALLEE_RE["condition"].search(ins.rest)
                mt = _TRIP_RE.search(ins.rest)
                trips = float(mt.group(1)) if mt else 1.0
                if mb:
                    walk(mb.group(1), mult * trips, inside_fusion, depth + 1)
                if mc:
                    walk(mc.group(1), mult * trips, inside_fusion, depth + 1)
            elif op == "fusion":
                m = _CALLEE_RE["calls"].search(ins.rest)
                if m:
                    walk(m.group(1), mult, True, depth + 1)
            elif op in ("call", "custom-call", "async-start"):
                m = (_CALLEE_RE["to_apply"].search(ins.rest)
                     or _CALLEE_RE["calls"].search(ins.rest))
                if m:
                    walk(m.group(1), mult, inside_fusion, depth + 1)

    walk(entry, 1.0, False)
    rows.sort(reverse=True)
    return rows[:n]
