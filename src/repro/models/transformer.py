"""Model composition: blocks -> segments -> full architectures.

A model is a sequence of *segments*; each segment is ``count`` identical
blocks whose parameters are stacked on a leading axis and executed with
``jax.lax.scan`` (key to keeping HLO size and compile time sane at 40-80
layer depths). Hybrid architectures interleave segments with a *shared*
attention block (single parameter set, Zamba2-style). Encoder-decoder
models own an encoder stack plus cross-attention in every decoder block.

Public API (all pure functions; ``Model`` is a thin namespace):
    build_model(cfg, model_axis) -> Model
    model.param_specs            ParamSpec tree
    model.init(key)              params
    model.partition_specs()      PartitionSpec tree
    model.abstract_params()      ShapeDtypeStruct tree
    model.forward(params, tokens, prompt=None, frontend=None)
        -> logits (B, S_total, V), aux (dict)
    model.init_cache(batch, cache_len) / model.abstract_cache(...)
    model.decode_step(params, cache, tokens, cache_len)
        -> logits (B, 1, V), new_cache
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (
    ParamSpec,
    abstract_tree,
    apply_ffn,
    apply_norm,
    embed_params,
    embed_tokens,
    ffn_params,
    materialize,
    maybe_model,
    norm_params,
    specs_tree,
    stack_specs,
    unembed,
)


@dataclass(frozen=True)
class Segment:
    kind: str          # dense | moe | rwkv | mamba | encoder | decoder_cross
    count: int
    name: str


# ---------------------------------------------------------------------------
# Per-block parameter trees
# ---------------------------------------------------------------------------


def _attn_params(cfg: ModelConfig, model_axis: int):
    if cfg.attention == "mla":
        return attn.mla_params(cfg, model_axis)
    return attn.gqa_params(cfg, model_axis)


def _dense_ffn_width(cfg: ModelConfig) -> int:
    """Width of the dense FFN in MoE models' first dense layers."""
    m = cfg.moe
    if m is None:
        return cfg.d_ff
    return m.d_ff_expert * (m.top_k + m.num_shared_experts)


def block_param_specs(cfg: ModelConfig, kind: str, model_axis: int,
                      data_axis: int = 0) -> Dict:
    d = cfg.d_model
    if kind == "dense":
        return {
            "ln1": norm_params(cfg, d),
            "attn": _attn_params(cfg, model_axis),
            "ln2": norm_params(cfg, d),
            "ffn": ffn_params(cfg, d, _dense_ffn_width(cfg), model_axis),
        }
    if kind == "moe":
        return {
            "ln1": norm_params(cfg, d),
            "attn": _attn_params(cfg, model_axis),
            "ln2": norm_params(cfg, d),
            "moe": moe_mod.moe_params(cfg, model_axis, data_axis),
        }
    if kind == "rwkv":
        return {
            "ln1": norm_params(cfg, d),
            "tmix": ssm_mod.rwkv6_params(cfg, model_axis),
            "ln2": norm_params(cfg, d),
            "ffn": ffn_params(cfg, d, cfg.d_ff, model_axis),
        }
    if kind == "mamba":
        return {
            "ln": norm_params(cfg, d),
            "mixer": ssm_mod.mamba2_params(cfg, model_axis),
        }
    if kind == "encoder":
        return {
            "ln1": norm_params(cfg, d),
            "attn": attn.gqa_params(cfg, model_axis),
            "ln2": norm_params(cfg, d),
            "ffn": ffn_params(cfg, d, cfg.d_ff, model_axis),
        }
    if kind == "decoder_cross":
        return {
            "ln1": norm_params(cfg, d),
            "attn": attn.gqa_params(cfg, model_axis),
            "lnx": norm_params(cfg, d),
            "cross": attn.cross_attention_params(cfg, model_axis),
            "ln2": norm_params(cfg, d),
            "ffn": ffn_params(cfg, d, cfg.d_ff, model_axis),
        }
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Per-block forward / decode
# ---------------------------------------------------------------------------


def _attn_forward(cfg, p, x, positions, causal=True):
    if cfg.attention == "mla":
        return attn.mla_forward(cfg, p, x, positions, causal=causal)
    return attn.gqa_forward(cfg, p, x, positions, causal=causal)


def block_forward(cfg: ModelConfig, kind: str, p: Dict, x, positions, ctx: Dict):
    """Returns (x, aux_scalar, new_state_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    state = None
    if kind in ("dense", "encoder"):
        causal = kind == "dense"
        if cfg.parallel_block:
            h = apply_norm(cfg, p["ln1"], x)
            x = x + _attn_forward(cfg, p["attn"], h, positions, causal) + apply_ffn(
                cfg, p["ffn"], h
            )
        else:
            x = x + _attn_forward(
                cfg, p["attn"], apply_norm(cfg, p["ln1"], x), positions, causal
            )
            x = x + apply_ffn(cfg, p["ffn"], apply_norm(cfg, p["ln2"], x))
    elif kind == "moe":
        x = x + _attn_forward(
            cfg, p["attn"], apply_norm(cfg, p["ln1"], x), positions, True
        )
        y, aux = moe_mod.moe_ffn(cfg, p["moe"], apply_norm(cfg, p["ln2"], x),
                                 mesh=ctx.get("mesh"))
        x = x + y
    elif kind == "rwkv":
        y, state = ssm_mod.rwkv6_forward(
            cfg, p["tmix"], apply_norm(cfg, p["ln1"], x), ctx.get("state")
        )
        x = x + y
        x = x + apply_ffn(cfg, p["ffn"], apply_norm(cfg, p["ln2"], x))
    elif kind == "mamba":
        y, state = ssm_mod.mamba2_forward(
            cfg, p["mixer"], apply_norm(cfg, p["ln"], x), ctx.get("state")
        )
        x = x + y
    elif kind == "decoder_cross":
        x = x + attn.gqa_forward(
            cfg, p["attn"], apply_norm(cfg, p["ln1"], x), positions, causal=True
        )
        x = x + attn.cross_attention(
            cfg, p["cross"], apply_norm(cfg, p["lnx"], x), ctx["enc_kv"]
        )
        x = x + apply_ffn(cfg, p["ffn"], apply_norm(cfg, p["ln2"], x))
    else:
        raise ValueError(kind)
    return x, aux, state


def block_decode(cfg: ModelConfig, kind: str, p: Dict, x, cache, cache_len, ctx):
    """One-token step. Returns (x, new_cache)."""
    if kind in ("dense", "moe"):
        h = apply_norm(cfg, p["ln1"], x)
        if cfg.attention == "mla":
            y, kv = attn.mla_decode(cfg, p["attn"], h, cache["kv"], cache_len)
        else:
            y, kv = attn.gqa_decode(cfg, p["attn"], h, cache["kv"], cache_len)
        if cfg.parallel_block and kind == "dense":
            x = x + y + apply_ffn(cfg, p["ffn"], h)
            return x, {"kv": kv}
        x = x + y
        if kind == "moe":
            y2, _ = moe_mod.moe_ffn(cfg, p["moe"], apply_norm(cfg, p["ln2"], x))
            x = x + y2
        else:
            x = x + apply_ffn(cfg, p["ffn"], apply_norm(cfg, p["ln2"], x))
        return x, {"kv": kv}
    if kind == "rwkv":
        y, st = ssm_mod.rwkv6_decode(
            cfg, p["tmix"], apply_norm(cfg, p["ln1"], x), cache["state"]
        )
        x = x + y
        x = x + apply_ffn(cfg, p["ffn"], apply_norm(cfg, p["ln2"], x))
        return x, {"state": st}
    if kind == "mamba":
        y, st = ssm_mod.mamba2_decode(
            cfg, p["mixer"], apply_norm(cfg, p["ln"], x), cache["state"]
        )
        return x + y, {"state": st}
    if kind == "decoder_cross":
        h = apply_norm(cfg, p["ln1"], x)
        y, kv = attn.gqa_decode(cfg, p["attn"], h, cache["kv"], cache_len)
        x = x + y
        x = x + attn.cross_attention(
            cfg, p["cross"], apply_norm(cfg, p["lnx"], x), ctx["enc_kv"]
        )
        x = x + apply_ffn(cfg, p["ffn"], apply_norm(cfg, p["ln2"], x))
        return x, {"kv": kv}
    raise ValueError(kind)


def block_cache(cfg: ModelConfig, kind: str, batch: int, length: int):
    dt = jnp.dtype(cfg.dtype)
    if kind in ("dense", "moe", "decoder_cross"):
        if cfg.attention == "mla":
            return {"kv": attn.mla_init_cache(cfg, batch, length, dt)}
        L = min(length, cfg.sliding_window) if cfg.sliding_window else length
        return {"kv": attn.gqa_init_cache(cfg, batch, L, dt)}
    if kind == "rwkv":
        return {"state": ssm_mod.rwkv6_init_state(cfg, batch)}
    if kind == "mamba":
        return {"state": ssm_mod.mamba2_init_state(cfg, batch)}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Model assembly
# ---------------------------------------------------------------------------


def plan_segments(cfg: ModelConfig) -> List[Segment]:
    at = cfg.arch_type
    L = cfg.num_layers
    if at in ("dense", "vlm"):
        return [Segment("dense", L, "blocks")]
    if at == "moe":
        fd = cfg.moe.first_dense_layers
        segs = []
        if fd:
            segs.append(Segment("dense", fd, "dense0"))
        segs.append(Segment("moe", L - fd, "moe"))
        return segs
    if at == "ssm":
        kind = "rwkv" if cfg.ssm.kind == "rwkv6" else "mamba"
        return [Segment(kind, L, "blocks")]
    if at == "hybrid":
        every = cfg.hybrid.attn_every
        segs = []
        i = 0
        g = 0
        while i < L:
            n = min(every, L - i)
            segs.append(Segment("mamba", n, f"mamba{g}"))
            i += n
            g += 1
        return segs
    if at == "audio":
        return [Segment("decoder_cross", L, "decoder")]
    raise ValueError(at)


class Model:
    def __init__(self, cfg: ModelConfig, model_axis: int = 1,
                 data_axis: int = 0, mesh=None):
        self.cfg = cfg
        self.model_axis = model_axis
        self.data_axis = data_axis
        self.mesh = mesh            # enables shard_map expert parallelism
        self.segments = plan_segments(cfg)
        self.param_specs = self._build_param_specs()

    # -- parameters ---------------------------------------------------------

    def _build_param_specs(self) -> Dict:
        cfg, ma = self.cfg, self.model_axis
        da = self.data_axis
        tree: Dict[str, Any] = {}
        tree.update(embed_params(cfg, ma))
        tree["final_norm"] = norm_params(cfg, cfg.d_model)
        for seg in self.segments:
            blk = block_param_specs(cfg, seg.kind, ma, da)
            tree[seg.name] = stack_specs(blk, seg.count)
        if cfg.hybrid is not None and cfg.hybrid.shared_attn:
            tree["shared_attn"] = {
                "ln": norm_params(cfg, cfg.d_model),
                "attn": attn.gqa_params(cfg, ma),
                "ln2": norm_params(cfg, cfg.d_model),
                "ffn": ffn_params(cfg, cfg.d_model, cfg.d_ff, ma),
            }
        if cfg.encdec is not None:
            enc_blk = block_param_specs(cfg, "encoder", ma)
            tree["encoder"] = {
                "blocks": stack_specs(enc_blk, cfg.encdec.num_encoder_layers),
                "final_norm": norm_params(cfg, cfg.d_model),
            }
        if cfg.frontend.kind != "none":
            tree["frontend_proj"] = ParamSpec(
                (cfg.frontend.embed_dim, cfg.d_model), P(None, None)
            )
        return tree

    def init(self, key: jax.Array):
        return materialize(self.param_specs, key, self.cfg.param_dtype)

    def partition_specs(self):
        return specs_tree(self.param_specs)

    def abstract_params(self):
        return abstract_tree(self.param_specs, self.cfg.param_dtype)

    # -- embedding of mixed inputs ------------------------------------------

    def embed_inputs(self, params, tokens, prompt=None, frontend=None):
        """[frontend embeddings][soft prompt][token embeddings] -> (B,S,d).

        prompt: (P, d) shared or (B, P, d); frontend: (B, F, e_frontend)."""
        cfg = self.cfg
        x = embed_tokens(params, tokens, cfg.dtype)
        B = x.shape[0]
        parts = []
        if frontend is not None:
            fe = (frontend @ params["frontend_proj"]).astype(x.dtype)
            parts.append(fe)
        if prompt is not None:
            pe = prompt.astype(x.dtype)
            if pe.ndim == 2:
                pe = jnp.broadcast_to(pe[None], (B, *pe.shape))
            parts.append(pe)
        parts.append(x)
        x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else x
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])
        return x, positions

    def _maybe_seq_shard(self, x):
        """Context parallelism: activations (B, S, d) sharded (data,
        model, -) when enabled and divisible. GSPMD then all-gathers K/V
        inside attention instead of replicating every (B, H, S, L) score
        tensor across the model axis."""
        cfg, mesh = self.cfg, self.mesh
        if not (cfg.seq_shard and mesh is not None
                and "model" in mesh.axis_names):
            return x
        from repro.models.common import constrain
        B, S, d = x.shape
        mp = mesh.shape["model"]
        if S % mp != 0:
            return x
        da = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        dp = 1
        for a in da:
            dp *= mesh.shape[a]
        batch_entry = None
        if B % dp == 0 and B >= dp:
            batch_entry = da if len(da) > 1 else da[0]
        return constrain(x, P(batch_entry, "model", None))

    # -- encoder (audio/enc-dec) ---------------------------------------------

    def encode(self, params, frontend):
        cfg = self.cfg
        x = (frontend @ params["frontend_proj"]).astype(jnp.dtype(cfg.dtype))
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])
        enc = params["encoder"]

        def body(h, lp):
            h, _, _ = block_forward(cfg, "encoder", lp, h, positions, {})
            return h, None

        fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(fn, x, enc["blocks"])
        return apply_norm(cfg, enc["final_norm"], x)

    # -- full forward (train / prefill) --------------------------------------

    def backbone(self, params, tokens, prompt=None, frontend=None):
        """Runs everything up to (and incl.) the final norm; returns
        (hidden (B,S,d), aux). Used by forward() and by the Prompt Bank's
        activation-feature extraction."""
        cfg = self.cfg
        ctx: Dict[str, Any] = {"mesh": self.mesh}
        if cfg.encdec is not None:
            enc_out = self.encode(params, frontend)
            frontend_dec = None
        else:
            enc_out = None
            frontend_dec = frontend
        x, positions = self.embed_inputs(params, tokens, prompt, frontend_dec)
        x = self._maybe_seq_shard(x)
        aux_total = jnp.zeros((), jnp.float32)

        for si, seg in enumerate(self.segments):
            stacked = params[seg.name]
            if seg.kind == "decoder_cross":
                # cross KV differs per layer: compute inside scan from enc_out
                def body(carry, lp):
                    h, aux = carry
                    ctx2 = {"enc_kv": attn.encode_cross_kv(cfg, lp["cross"], enc_out)}
                    h, a, _ = block_forward(cfg, seg.kind, lp, h, positions, ctx2)
                    return (h, aux + a), None

                fn = jax.checkpoint(body) if cfg.remat else body
                (x, aux_total), _ = jax.lax.scan(fn, (x, aux_total), stacked)
            elif seg.kind in ("rwkv", "mamba"):
                def body(carry, lp):
                    h, aux = carry
                    h, a, _ = block_forward(cfg, seg.kind, lp, h, positions,
                                            {"state": None})
                    return (h, aux + a), None

                fn = jax.checkpoint(body) if cfg.remat else body
                (x, aux_total), _ = jax.lax.scan(fn, (x, aux_total), stacked)
            else:
                def body(carry, lp):
                    h, aux = carry
                    h, a, _ = block_forward(cfg, seg.kind, lp, h, positions, ctx)
                    return (h, aux + a), None

                fn = jax.checkpoint(body) if cfg.remat else body
                (x, aux_total), _ = jax.lax.scan(fn, (x, aux_total), stacked)
            # Zamba2-style shared attention between SSM segments
            if (
                cfg.hybrid is not None
                and cfg.hybrid.shared_attn
                and seg.kind == "mamba"
                and si < len(self.segments) - 1
            ):
                sa = params["shared_attn"]
                x = x + attn.gqa_forward(
                    cfg, sa["attn"], apply_norm(cfg, sa["ln"], x), positions,
                    causal=True,
                )
                x = x + apply_ffn(cfg, sa["ffn"], apply_norm(cfg, sa["ln2"], x))

        x = apply_norm(cfg, params["final_norm"], x)
        return x, {"aux_loss": aux_total}

    def forward(self, params, tokens, prompt=None, frontend=None):
        """Returns (logits (B,S_total,V) f32, aux dict)."""
        x, aux = self.backbone(params, tokens, prompt, frontend)
        return unembed(self.cfg, params, x), aux

    # -- caches ---------------------------------------------------------------

    def _seg_cache(self, seg: Segment, batch: int, length: int):
        one = block_cache(self.cfg, seg.kind, batch, length)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (seg.count, *a.shape)), one
        )

    def init_cache(self, batch: int, length: int):
        cache: Dict[str, Any] = {
            seg.name: self._seg_cache(seg, batch, length) for seg in self.segments
        }
        cfg = self.cfg
        if cfg.hybrid is not None and cfg.hybrid.shared_attn:
            # shared WEIGHTS, but one KV cache per application depth
            n_apps = max(len(self.segments) - 1, 0)
            cache["shared_attn"] = {
                f"app{i}": block_cache(cfg, "dense", batch, length)
                for i in range(n_apps)
            }
        if cfg.encdec is not None:
            # cross-attention KV per decoder layer, precomputed at prefill
            Hkv, hd = cfg.kv_heads(), cfg.resolved_head_dim()
            Lenc = cfg.encdec.encoder_seq_len
            n = self.segments[0].count
            cache["cross_kv"] = {
                "k": jnp.zeros((n, batch, Lenc, Hkv, hd), jnp.dtype(cfg.dtype)),
                "v": jnp.zeros((n, batch, Lenc, Hkv, hd), jnp.dtype(cfg.dtype)),
            }
        return cache

    def abstract_cache(self, batch: int, length: int):
        return jax.eval_shape(lambda: self.init_cache(batch, length))

    # -- decode step ------------------------------------------------------------

    def decode_step(self, params, cache, tokens, cache_len):
        """tokens: (B,1) int32; cache_len: scalar int32 (tokens already cached)."""
        cfg = self.cfg
        x = embed_tokens(params, tokens, cfg.dtype)
        new_cache: Dict[str, Any] = {}

        for si, seg in enumerate(self.segments):
            stacked_p = params[seg.name]
            stacked_c = cache[seg.name]
            if seg.kind == "decoder_cross":
                xkv = cache["cross_kv"]

                def body(h, xs):
                    lp, lc, ck, cv = xs
                    h, c2 = block_decode(
                        cfg, seg.kind, lp, h, lc, cache_len,
                        {"enc_kv": (ck, cv)},
                    )
                    return h, c2

                x, seg_cache = jax.lax.scan(
                    body, x, (stacked_p, stacked_c, xkv["k"], xkv["v"])
                )
                new_cache["cross_kv"] = xkv
            else:
                def body(h, xs):
                    lp, lc = xs
                    h, c2 = block_decode(cfg, seg.kind, lp, h, lc, cache_len, {})
                    return h, c2

                x, seg_cache = jax.lax.scan(body, x, (stacked_p, stacked_c))
            new_cache[seg.name] = seg_cache
            if (
                cfg.hybrid is not None
                and cfg.hybrid.shared_attn
                and seg.kind == "mamba"
                and si < len(self.segments) - 1
            ):
                sa = params["shared_attn"]
                app = f"app{si}"
                y, kv = attn.gqa_decode(
                    cfg, sa["attn"], apply_norm(cfg, sa["ln"], x),
                    cache["shared_attn"][app]["kv"], cache_len,
                )
                x = x + y
                x = x + apply_ffn(cfg, sa["ffn"], apply_norm(cfg, sa["ln2"], x))
                new_cache.setdefault("shared_attn", {})[app] = {"kv": kv}

        x = apply_norm(cfg, params["final_norm"], x)
        logits = unembed(cfg, params, x)
        return logits, new_cache


def build_model(cfg: ModelConfig, model_axis: int = 1,
                data_axis: int = 0, mesh=None) -> Model:
    return Model(cfg, model_axis, data_axis, mesh)
