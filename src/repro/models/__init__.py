from repro.models.transformer import Model, build_model, plan_segments

__all__ = ["Model", "build_model", "plan_segments"]
