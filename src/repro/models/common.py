"""Common model machinery: parameter descriptors, init, norms, rope, FFN.

Parameters are plain pytrees (nested dicts) of jnp arrays. Each module
defines its parameters once as a tree of :class:`ParamSpec` descriptors —
a single source of truth for shape, sharding (PartitionSpec) and
initializer — from which we derive (a) materialized params, (b) the
NamedSharding tree for pjit, and (c) ShapeDtypeStructs for dry-runs.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig

# ---------------------------------------------------------------------------
# Parameter descriptors
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    spec: Any = None                     # PartitionSpec or None (replicated)
    init: str = "normal"                 # normal | zeros | ones | small | decay
    scale: float = 1.0
    dtype: Optional[str] = None          # override param dtype


def _init_array(ps: ParamSpec, key: jax.Array, default_dtype: str) -> jax.Array:
    dtype = ps.dtype or default_dtype
    if ps.init == "zeros":
        return jnp.zeros(ps.shape, dtype)
    if ps.init == "ones":
        return jnp.ones(ps.shape, dtype)
    if ps.init == "decay":
        # rwkv-style decay init: spread in [-6, -1] pre-softplus
        n = math.prod(ps.shape)
        vals = jnp.linspace(-6.0, -1.0, n).reshape(ps.shape)
        return vals.astype(dtype)
    fan_in = ps.shape[-2] if len(ps.shape) >= 2 else ps.shape[-1]
    std = ps.scale / math.sqrt(max(fan_in, 1))
    if ps.init == "small":
        std = 0.02 * ps.scale
    return (jax.random.normal(key, ps.shape, jnp.float32) * std).astype(dtype)


def is_param_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_paths(tree) -> Dict[str, ParamSpec]:
    flat = {}

    def walk(prefix, node):
        if is_param_spec(node):
            flat[prefix] = node
        elif isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}/{k}" if prefix else k, v)
        else:
            raise TypeError(f"bad node at {prefix}: {type(node)}")

    walk("", tree)
    return flat


def materialize(tree, key: jax.Array, param_dtype: str):
    """Materialize a ParamSpec tree into arrays, deterministic per path."""
    flat = tree_paths(tree)
    names = sorted(flat)
    keys = jax.random.split(key, len(names))
    arrays = {
        name: _init_array(flat[name], k, param_dtype)
        for name, k in zip(names, keys)
    }

    def rebuild(prefix, node):
        if is_param_spec(node):
            return arrays[prefix]
        return {
            k: rebuild(f"{prefix}/{k}" if prefix else k, v) for k, v in node.items()
        }

    return rebuild("", tree)


def specs_tree(tree):
    """ParamSpec tree -> PartitionSpec tree (replicated leaves become P())."""
    return jax.tree.map(
        lambda ps: ps.spec if ps.spec is not None else P(),
        tree,
        is_leaf=is_param_spec,
    )


def abstract_tree(tree, param_dtype: str):
    """ParamSpec tree -> ShapeDtypeStruct tree (for dry-run lowering)."""
    return jax.tree.map(
        lambda ps: jax.ShapeDtypeStruct(ps.shape, jnp.dtype(ps.dtype or param_dtype)),
        tree,
        is_leaf=is_param_spec,
    )


def stack_specs(tree, n: int):
    """Prepend a stacking dim of size n (for scan-over-layers params)."""

    def bump(ps: ParamSpec) -> ParamSpec:
        spec = ps.spec
        if spec is None:
            spec = P()
        new_spec = P(None, *tuple(spec))
        return dataclasses.replace(ps, shape=(n, *ps.shape), spec=new_spec)

    return jax.tree.map(bump, tree, is_leaf=is_param_spec)


def shard_if_divisible(n: int, axis: str, mesh_axis_size: int) -> Optional[str]:
    """Return the mesh axis name if ``n`` divides evenly over it."""
    return axis if n % mesh_axis_size == 0 and n >= mesh_axis_size else None


# Mesh axis size used for *spec construction*. Specs name logical axes;
# whether a dim is actually shardable is resolved when we know the mesh.
MODEL_AXIS = "model"


def maybe_model(n: int, model_axis_size: int) -> Optional[str]:
    return MODEL_AXIS if model_axis_size > 0 and n % model_axis_size == 0 else None


def constrain(x: jax.Array, spec) -> jax.Array:
    """Best-effort ``with_sharding_constraint``: a no-op when no mesh is
    active (CPU smoke tests) so model code can annotate layouts freely."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:            # no mesh / axis not present
        return x


# ---------------------------------------------------------------------------
# Numerics / layers (pure functions over param dicts)
# ---------------------------------------------------------------------------


def cast(x, dtype_str: str):
    return x.astype(jnp.dtype(dtype_str))


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)
    return out.astype(dt)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return out.astype(dt)


def norm_params(cfg: ModelConfig, d: int) -> Dict[str, ParamSpec]:
    if cfg.norm == "layernorm":
        return {
            "gamma": ParamSpec((d,), P(), "ones", dtype="float32"),
            "beta": ParamSpec((d,), P(), "zeros", dtype="float32"),
        }
    return {"gamma": ParamSpec((d,), P(), "ones", dtype="float32")}


def apply_norm(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layer_norm(x, p["gamma"], p["beta"])
    return rms_norm(x, p["gamma"])


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                     # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def ffn_params(cfg: ModelConfig, d_model: int, d_ff: int, model_axis: int):
    mf = maybe_model(d_ff, model_axis)
    p = {
        "w_up": ParamSpec((d_model, d_ff), P(None, mf)),
        "w_down": ParamSpec((d_ff, d_model), P(mf, None)),
    }
    if cfg.activation == "swiglu":
        p["w_gate"] = ParamSpec((d_model, d_ff), P(None, mf))
    return p


def apply_ffn(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    up = x @ p["w_up"]
    if cfg.activation == "swiglu":
        act = jax.nn.silu(x @ p["w_gate"]) * up
    else:
        act = jax.nn.gelu(up)
    return act @ p["w_down"]


def embed_params(cfg: ModelConfig, model_axis: int):
    mv = maybe_model(cfg.vocab_size, model_axis)
    p = {"embedding": ParamSpec((cfg.vocab_size, cfg.d_model), P(mv, None), "small")}
    if not cfg.tie_embeddings:
        p["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size), P(None, mv), "small")
    return p


def embed_tokens(p, tokens: jax.Array, dtype: str) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0).astype(jnp.dtype(dtype))


def unembed(cfg: ModelConfig, p, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", h, p["embedding"]).astype(jnp.float32)
    else:
        logits = (h @ p["unembed"]).astype(jnp.float32)
    if cfg.logit_soft_cap > 0:
        c = cfg.logit_soft_cap
        logits = c * jnp.tanh(logits / c)
    return logits
