"""State-space / linear-attention blocks: RWKV6 (Finch) and Mamba2 (SSD).

Both use a *chunked* parallel scan: within a chunk the token-vs-token decay
matrix is materialized (all exponents are <= 0, so this is numerically
safe), across chunks a recurrent state is carried with ``jax.lax.scan``.
This is the TPU-native mapping of the papers' CUDA scan kernels: the
intra-chunk work is MXU matmuls, the sequential dependency is only at
chunk granularity.

State layouts:
  rwkv6:  S (B, H, hd, hd)  + token-shift x_prev (B, d_model)
  mamba2: h (B, H, d_state, head_dim) + conv ring (B, conv_w-1, d_conv_ch)
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models.common import ParamSpec, maybe_model, rms_norm

TIME_MIX_DIM = 32
DECAY_LORA_DIM = 64


# ===========================================================================
# RWKV6
# ===========================================================================


def rwkv6_dims(cfg: ModelConfig) -> Tuple[int, int]:
    hd = cfg.ssm.state_size
    H = cfg.ssm.num_heads or cfg.d_model // hd
    return H, hd


def rwkv6_params(cfg: ModelConfig, model_axis: int) -> Dict:
    d = cfg.d_model
    H, hd = rwkv6_dims(cfg)
    da = H * hd
    mh = maybe_model(H, model_axis)
    return {
        # data-dependent token-shift (ddlerp) mixing
        "mu_x": ParamSpec((d,), P(), "small"),
        "mu_rkvwg": ParamSpec((5, d), P(), "small"),
        "tm_w1": ParamSpec((d, 5 * TIME_MIX_DIM), P(), "small"),
        "tm_w2": ParamSpec((5, TIME_MIX_DIM, d), P(), "small"),
        # projections
        "wr": ParamSpec((d, H, hd), P(None, mh, None)),
        "wk": ParamSpec((d, H, hd), P(None, mh, None)),
        "wv": ParamSpec((d, H, hd), P(None, mh, None)),
        "wg": ParamSpec((d, da), P(None, maybe_model(da, model_axis))),
        "wo": ParamSpec((H, hd, d), P(mh, None, None)),
        # data-dependent decay
        "w0": ParamSpec((H, hd), P(mh, None), "decay", dtype="float32"),
        "decay_w1": ParamSpec((d, DECAY_LORA_DIM), P(), "small"),
        "decay_w2": ParamSpec((DECAY_LORA_DIM, H, hd), P(None, mh, None), "small"),
        # per-channel current-token bonus
        "u": ParamSpec((H, hd), P(mh, None), "small", dtype="float32"),
        "ln_out": ParamSpec((H, hd), P(mh, None), "ones", dtype="float32"),
    }


def _rwkv6_inputs(cfg, p, x, x_prev):
    """ddlerp token shift -> r,k,v,g,logw. x: (B,S,d); x_prev: (B,d)."""
    B, S, d = x.shape
    H, hd = rwkv6_dims(cfg)
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    xx = shifted - x
    xxx = x + xx * p["mu_x"]
    lora = jnp.tanh(xxx @ p["tm_w1"]).reshape(B, S, 5, TIME_MIX_DIM)
    mixes = p["mu_rkvwg"] + jnp.einsum("bstm,tmd->bstd", lora, p["tm_w2"])
    xr, xk, xv, xw, xg = [x + xx * mixes[:, :, i] for i in range(5)]
    r = jnp.einsum("bsd,dhk->bshk", xr, p["wr"])
    k = jnp.einsum("bsd,dhk->bshk", xk, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xv, p["wv"])
    g = jax.nn.silu(xg @ p["wg"]).reshape(B, S, H, hd)
    dw = jnp.einsum("bsl,lhk->bshk", jnp.tanh(xw @ p["decay_w1"]), p["decay_w2"])
    logw = -jnp.exp((p["w0"] + dw).astype(jnp.float32))            # (B,S,H,hd) <= 0
    logw = jnp.maximum(logw, -12.0)
    return r, k, v, g, logw


def _rwkv6_chunk(r, k, v, logw, u, state):
    """One chunk. r/k/v: (B,H,Lc,hd) f32; logw: same (<=0); u: (H,hd);
    state: (B,H,hd,hd) [k-dim x v-dim]. Returns y, new_state."""
    B, H, Lc, hd = r.shape
    c = jnp.cumsum(logw, axis=2)                                   # inclusive
    b = c - logw                                                   # exclusive
    # decay matrix D[i,j,d] = exp(b_i - c_j) for j<i; u for j==i; 0 for j>i
    diff = b[:, :, :, None, :] - c[:, :, None, :, :]               # (B,H,Lc,Lc,hd)
    ii = jnp.arange(Lc)
    lower = (ii[:, None] > ii[None, :])[None, None, :, :, None]
    diag = (ii[:, None] == ii[None, :])[None, None, :, :, None]
    D = jnp.where(lower, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    D = D + diag * u[None, :, None, None, :]
    score = jnp.einsum("bhid,bhjd,bhijd->bhij", r, k, D)
    y = jnp.einsum("bhij,bhje->bhie", score, v)
    y = y + jnp.einsum("bhid,bhde->bhie", r * jnp.exp(b), state)
    kd = k * jnp.exp(c[:, :, -1:, :] - c)                          # (B,H,Lc,hd)
    state_new = jnp.exp(c[:, :, -1, :])[..., None] * state + jnp.einsum(
        "bhjd,bhje->bhde", kd, v
    )
    return y, state_new


def rwkv6_forward(cfg: ModelConfig, p: Dict, x: jax.Array, state=None):
    """x: (B,S,d). Returns (y (B,S,d), state dict)."""
    B, S, d = x.shape
    H, hd = rwkv6_dims(cfg)
    Lc = min(cfg.ssm.chunk_size, S)
    if state is None:
        state = rwkv6_init_state(cfg, B)
    r, k, v, g, logw = _rwkv6_inputs(cfg, p, x, state["x_prev"])
    # to (B,H,S,hd) f32
    tr = lambda t: t.transpose(0, 2, 1, 3).astype(jnp.float32)
    r_, k_, v_, w_ = tr(r), tr(k), tr(v), logw.transpose(0, 2, 1, 3)
    nchunks = -(-S // Lc)
    pad = nchunks * Lc - S
    if pad:
        zp = lambda t: jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0)))
        r_, k_, v_ = zp(r_), zp(k_), zp(v_)
        w_ = jnp.pad(w_, ((0, 0), (0, 0), (0, pad), (0, 0)))       # logw=0 => w=1, k=0
    ch = lambda t: t.reshape(B, H, nchunks, Lc, hd).transpose(2, 0, 1, 3, 4)
    u = p["u"].astype(jnp.float32)

    def body(s, blk):
        rc, kc, vc, wc = blk
        y, s2 = _rwkv6_chunk(rc, kc, vc, wc, u, s)
        return s2, y

    s_final, ys = jax.lax.scan(body, state["s"].astype(jnp.float32),
                               (ch(r_), ch(k_), ch(v_), ch(w_)))
    # ys: (nchunks, B, H, Lc, hd) -> (B, H, S, hd)
    y = ys.transpose(1, 2, 0, 3, 4).reshape(B, H, nchunks * Lc, hd)[:, :, :S]
    y = y.transpose(0, 2, 1, 3)                                    # (B,S,H,hd)
    # per-head group norm, gate, output projection
    y = rms_norm(y, jnp.ones((hd,), jnp.float32)) * p["ln_out"]
    y = (y * g.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", y, p["wo"])
    new_state = {"s": s_final.astype(jnp.float32), "x_prev": x[:, -1, :]}
    return out, new_state


def rwkv6_init_state(cfg: ModelConfig, batch: int) -> Dict:
    H, hd = rwkv6_dims(cfg)
    return {
        "s": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "x_prev": jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.dtype)),
    }


def rwkv6_decode(cfg: ModelConfig, p: Dict, x: jax.Array, state: Dict):
    """One-token step. x: (B,1,d)."""
    B = x.shape[0]
    H, hd = rwkv6_dims(cfg)
    r, k, v, g, logw = _rwkv6_inputs(cfg, p, x, state["x_prev"])
    r_, k_, v_ = (t[:, 0].astype(jnp.float32) for t in (r, k, v))  # (B,H,hd)
    w = jnp.exp(logw[:, 0])                                        # (B,H,hd)
    s = state["s"]
    kv = jnp.einsum("bhd,bhe->bhde", k_, v_)
    u = p["u"].astype(jnp.float32)
    y = jnp.einsum("bhd,bhde->bhe", r_, s + u[None, :, :, None] * kv)
    s_new = w[..., None] * s + kv
    y = rms_norm(y, jnp.ones((hd,), jnp.float32)) * p["ln_out"]
    y = (y[:, None] * g.astype(jnp.float32)).astype(x.dtype)       # (B,1,H,hd)
    out = jnp.einsum("bshk,hkd->bsd", y, p["wo"])
    return out, {"s": s_new, "x_prev": x[:, -1, :]}


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================


def mamba2_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_inner = cfg.ssm.expand * cfg.d_model
    head_dim = 64
    H = d_inner // head_dim
    return d_inner, H, head_dim


def mamba2_params(cfg: ModelConfig, model_axis: int) -> Dict:
    d = cfg.d_model
    ds = cfg.ssm.state_size
    d_inner, H, hd = mamba2_dims(cfg)
    conv_ch = d_inner + 2 * ds
    mi = maybe_model(d_inner, model_axis)
    mh = maybe_model(H, model_axis)
    return {
        "in_z": ParamSpec((d, d_inner), P(None, mi)),
        "in_x": ParamSpec((d, d_inner), P(None, mi)),
        "in_B": ParamSpec((d, ds), P()),
        "in_C": ParamSpec((d, ds), P()),
        "in_dt": ParamSpec((d, H), P(None, mh)),
        "dt_bias": ParamSpec((H,), P(), "zeros", dtype="float32"),
        "conv_w": ParamSpec((cfg.ssm.conv_width, conv_ch), P(), "small"),
        "conv_b": ParamSpec((conv_ch,), P(), "zeros"),
        "a_log": ParamSpec((H,), P(), "decay", dtype="float32"),
        "d_skip": ParamSpec((H,), P(), "ones", dtype="float32"),
        "norm_g": ParamSpec((d_inner,), P(mi), "ones", dtype="float32"),
        "out": ParamSpec((d_inner, d), P(mi, None)),
    }


def _causal_conv(xBC, w, b, init_state=None):
    """Depthwise causal conv. xBC: (B,S,C); w: (W,C). init_state: (B,W-1,C)."""
    W = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((xBC.shape[0], W - 1, xBC.shape[-1]), xBC.dtype)
    xp = jnp.concatenate([init_state, xBC], axis=1)
    out = sum(xp[:, i : i + xBC.shape[1], :] * w[i] for i in range(W))
    tail = xp[:, -(W - 1) :, :]
    return jax.nn.silu(out + b), tail


def _mamba2_chunk(C, Bm, xh, dt, loglam, h0):
    """One SSD chunk. C/Bm: (B,H,Lc,ds); xh: (B,H,Lc,hd); dt: (B,H,Lc);
    loglam: (B,H,Lc) (<=0); h0: (B,H,ds,hd)."""
    cum = jnp.cumsum(loglam, axis=2)
    Lc = dt.shape[2]
    ii = jnp.arange(Lc)
    tri = (ii[:, None] >= ii[None, :])[None, None]
    L = jnp.where(tri, jnp.exp(jnp.minimum(cum[:, :, :, None] - cum[:, :, None, :], 0.0)), 0.0)
    score = jnp.einsum("bhin,bhjn->bhij", C, Bm) * L * dt[:, :, None, :]
    y = jnp.einsum("bhij,bhjd->bhid", score, xh)
    y = y + jnp.einsum("bhin,bhnd->bhid", C * jnp.exp(cum)[..., None], h0)
    w = dt * jnp.exp(cum[:, :, -1:] - cum)
    h_new = jnp.exp(cum[:, :, -1])[..., None, None] * h0 + jnp.einsum(
        "bhjn,bhjd->bhnd", Bm * w[..., None], xh
    )
    return y, h_new


def mamba2_init_state(cfg: ModelConfig, batch: int) -> Dict:
    ds = cfg.ssm.state_size
    d_inner, H, hd = mamba2_dims(cfg)
    conv_ch = d_inner + 2 * ds
    return {
        "h": jnp.zeros((batch, H, ds, hd), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm.conv_width - 1, conv_ch), jnp.dtype(cfg.dtype)),
    }


def _mamba2_proj(cfg, p, x):
    z = x @ p["in_z"]
    xBC = jnp.concatenate([x @ p["in_x"], x @ p["in_B"], x @ p["in_C"]], axis=-1)
    dt = jax.nn.softplus((x @ p["in_dt"]).astype(jnp.float32) + p["dt_bias"])
    return z, xBC, dt


def _mamba2_split(cfg, xBC):
    ds = cfg.ssm.state_size
    d_inner, H, hd = mamba2_dims(cfg)
    xh = xBC[..., :d_inner]
    Bm = xBC[..., d_inner : d_inner + ds]
    C = xBC[..., d_inner + ds :]
    return xh, Bm, C


def mamba2_forward(cfg: ModelConfig, p: Dict, x: jax.Array, state=None):
    B, S, d = x.shape
    ds = cfg.ssm.state_size
    d_inner, H, hd = mamba2_dims(cfg)
    Lc = min(cfg.ssm.chunk_size, S)
    if state is None:
        state = mamba2_init_state(cfg, B)
    z, xBC, dt = _mamba2_proj(cfg, p, x)
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], state["conv"])
    xh, Bm, C = _mamba2_split(cfg, xBC)
    a = -jnp.exp(p["a_log"])                                       # (H,) < 0
    loglam = dt * a                                                # (B,S,H)

    nchunks = -(-S // Lc)
    pad = nchunks * Lc - S
    xh4 = xh.reshape(B, S, H, hd).transpose(0, 2, 1, 3).astype(jnp.float32)
    Bm4 = jnp.broadcast_to(Bm[:, :, None, :], (B, S, H, ds)).transpose(0, 2, 1, 3).astype(jnp.float32)
    C4 = jnp.broadcast_to(C[:, :, None, :], (B, S, H, ds)).transpose(0, 2, 1, 3).astype(jnp.float32)
    dt4 = dt.transpose(0, 2, 1)
    ll4 = loglam.transpose(0, 2, 1)
    if pad:
        zp4 = lambda t: jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0)))
        zp3 = lambda t: jnp.pad(t, ((0, 0), (0, 0), (0, pad)))
        xh4, Bm4, C4 = zp4(xh4), zp4(Bm4), zp4(C4)
        dt4, ll4 = zp3(dt4), zp3(ll4)
    ch4 = lambda t: t.reshape(B, H, nchunks, Lc, t.shape[-1]).transpose(2, 0, 1, 3, 4)
    ch3 = lambda t: t.reshape(B, H, nchunks, Lc).transpose(2, 0, 1, 3)

    def body(h, blk):
        Cc, Bc, xc, dtc, llc = blk
        y, h2 = _mamba2_chunk(Cc, Bc, xc, dtc, llc, h)
        return h2, y

    h_final, ys = jax.lax.scan(
        body, state["h"], (ch4(C4), ch4(Bm4), ch4(xh4), ch3(dt4), ch3(ll4))
    )
    # ys: (nchunks, B, H, Lc, hd) -> (B, H, S, hd)
    y = ys.transpose(1, 2, 0, 3, 4).reshape(B, H, nchunks * Lc, hd)[:, :, :S]
    y = y + p["d_skip"][None, :, None, None] * xh4[:, :, :S]
    y = y.transpose(0, 2, 1, 3).reshape(B, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm_g"]).astype(x.dtype)
    out = y @ p["out"]
    return out, {"h": h_final, "conv": conv_state}


def mamba2_decode(cfg: ModelConfig, p: Dict, x: jax.Array, state: Dict):
    """One-token step. x: (B,1,d)."""
    B = x.shape[0]
    ds = cfg.ssm.state_size
    d_inner, H, hd = mamba2_dims(cfg)
    z, xBC, dt = _mamba2_proj(cfg, p, x)
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], state["conv"])
    xh, Bm, C = _mamba2_split(cfg, xBC)
    xh = xh[:, 0].reshape(B, H, hd).astype(jnp.float32)
    Bm = Bm[:, 0].astype(jnp.float32)
    C = C[:, 0].astype(jnp.float32)
    dt1 = dt[:, 0]                                                 # (B,H)
    a = -jnp.exp(p["a_log"])
    lam = jnp.exp(dt1 * a)                                         # (B,H)
    h = state["h"] * lam[..., None, None] + jnp.einsum(
        "bn,bhd->bhnd", Bm, xh * dt1[..., None]
    )
    y = jnp.einsum("bn,bhnd->bhd", C, h) + p["d_skip"][None, :, None] * xh
    y = y.reshape(B, 1, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm_g"]).astype(x.dtype)
    return y @ p["out"], {"h": h, "conv": conv_state}
