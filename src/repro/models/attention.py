"""Attention families: GQA (full / sliding-window / blockwise online-softmax)
and MLA (DeepSeek-V2 multi-head latent attention, with the absorbed decode).

All functions are pure; caches are dicts of arrays. Sequence positions are
absolute (soft prompt / frontend embeddings occupy the leading positions).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models.common import (
    ParamSpec,
    apply_rope,
    maybe_model,
    norm_params,
    apply_norm,
)

NEG_INF = -1e30
_PLAIN_ATTN_MAX_KV = 4096   # use blockwise online softmax above this
_KV_BLOCK = 1024


def _flash_decode_default() -> bool:
    """Auto-gate for the Pallas decode kernels: on for real TPUs, off on
    CPU so the sim/test XLA paths (and their goldens) are untouched."""
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Core softmax attention (shared by GQA / MLA / cross-attention)
# ---------------------------------------------------------------------------


def _plain_attention(q, k, v, mask, scale):
    """q: (B,S,Hkv,G,hd) k,v: (B,L,Hkv,hd) mask: (B,S,L) or None."""
    scores = jnp.einsum("bshgd,blhd->bhgsl", q, k).astype(jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgsl,blhd->bshgd", probs, v)
    return out


def _blockwise_attention(q, k, v, q_pos, kv_pos, kv_valid, scale, causal, window):
    """Online-softmax attention, scanning KV blocks. Memory O(S * block).

    q: (B,S,Hkv,G,hd); k,v: (B,L,Hkv,hd); q_pos: (B,S); kv_pos: (B,L).
    kv_valid: (B,L) bool. Returns (B,S,Hkv,G,hd).
    """
    B, S, Hkv, G, hd = q.shape
    hd_v = v.shape[-1]              # MLA: value head dim != qk head dim
    L = k.shape[1]
    nb = -(-L // _KV_BLOCK)
    pad = nb * _KV_BLOCK - L
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)))
        kv_valid = jnp.pad(kv_valid, ((0, 0), (0, pad)))
    kb = k.reshape(B, nb, _KV_BLOCK, Hkv, hd)
    vb = v.reshape(B, nb, _KV_BLOCK, Hkv, hd_v)
    pb = kv_pos.reshape(B, nb, _KV_BLOCK)
    validb = kv_valid.reshape(B, nb, _KV_BLOCK)

    m0 = jnp.full((B, Hkv, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, S), jnp.float32)
    acc0 = jnp.zeros((B, S, Hkv, G, hd_v), jnp.float32)

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, pblk, vldblk = blk
        s = jnp.einsum("bshgd,blhd->bhgsl", q, kblk).astype(jnp.float32) * scale
        ok = vldblk[:, None, :]                                   # (B,1,L)
        if causal:
            ok = ok & (pblk[:, None, :] <= q_pos[:, :, None])
        if window and window > 0:
            ok = ok & (pblk[:, None, :] > q_pos[:, :, None] - window)
        s = jnp.where(ok[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + pexp.sum(axis=-1)
        acc_new = acc * alpha.transpose(0, 3, 1, 2)[..., None] + jnp.einsum(
            "bhgsl,blhd->bshgd", pexp.astype(vblk.dtype), vblk
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    blks = (
        kb.transpose(1, 0, 2, 3, 4),
        vb.transpose(1, 0, 2, 3, 4),
        pb.transpose(1, 0, 2),
        validb.transpose(1, 0, 2),
    )
    # flash-attention memory behaviour in the backward pass too: recompute
    # per-block scores instead of saving every (B,H,G,S,block) tensor
    body = jax.checkpoint(body, prevent_cse=False)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), blks)
    denom = jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return (acc / denom).astype(q.dtype)


def scaled_attention(
    q, k, v, *, q_pos, kv_pos, kv_valid=None, causal=True, window=0, scale=None
):
    """Dispatcher: plain masked attention for short KV, blockwise otherwise."""
    B, S, Hkv, G, hd = q.shape
    L = k.shape[1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    if kv_valid is None:
        kv_valid = jnp.ones((B, L), bool)
    if L <= _PLAIN_ATTN_MAX_KV:
        mask = kv_valid[:, None, :]
        if causal:
            mask = mask & (kv_pos[:, None, :] <= q_pos[:, :, None])
        if window and window > 0:
            mask = mask & (kv_pos[:, None, :] > q_pos[:, :, None] - window)
        return _plain_attention(q, k, v, mask, scale)
    return _blockwise_attention(q, k, v, q_pos, kv_pos, kv_valid, scale, causal, window)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_params(cfg: ModelConfig, model_axis: int) -> Dict:
    hd = cfg.resolved_head_dim()
    H, Hkv = cfg.num_heads, cfg.kv_heads()
    mh = maybe_model(H, model_axis)
    mkv = maybe_model(Hkv, model_axis)
    p = {
        "wq": ParamSpec((cfg.d_model, H, hd), P(None, mh, None)),
        "wk": ParamSpec((cfg.d_model, Hkv, hd), P(None, mkv, None)),
        "wv": ParamSpec((cfg.d_model, Hkv, hd), P(None, mkv, None)),
        "wo": ParamSpec((H, hd, cfg.d_model), P(mh, None, None)),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((H, hd), P(mh, None), "zeros")
        p["bk"] = ParamSpec((Hkv, hd), P(mkv, None), "zeros")
        p["bv"] = ParamSpec((Hkv, hd), P(mkv, None), "zeros")
    return p


def _qkv(cfg: ModelConfig, p, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_forward(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    return_kv: bool = False,
):
    """Full-sequence attention (training / prefill)."""
    B, S, _ = x.shape
    H, Hkv = cfg.num_heads, cfg.kv_heads()
    hd = cfg.resolved_head_dim()
    G = H // Hkv
    q, k, v = _qkv(cfg, p, x, positions)
    qg = q.reshape(B, S, Hkv, G, hd)
    w = cfg.sliding_window if window is None else window
    out = scaled_attention(
        qg, k, v, q_pos=positions, kv_pos=positions, causal=causal, window=w
    )
    y = jnp.einsum("bshgd,hgdk->bsk", out.reshape(B, S, Hkv, G, hd),
                   p["wo"].reshape(Hkv, G, hd, cfg.d_model))
    if return_kv:
        return y, (k, v)
    return y


def gqa_init_cache(cfg: ModelConfig, batch: int, length: int, dtype) -> Dict:
    Hkv, hd = cfg.kv_heads(), cfg.resolved_head_dim()
    return {
        "k": jnp.zeros((batch, length, Hkv, hd), dtype),
        "v": jnp.zeros((batch, length, Hkv, hd), dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),
    }


def gqa_decode(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,                 # (B, 1, d_model)
    cache: Dict,
    cache_len: jax.Array,         # scalar int32: tokens already in cache
    *,
    window: Optional[int] = None,
    use_flash: Optional[bool] = None,
) -> Tuple[jax.Array, Dict]:
    """One decode step against a (possibly ring-buffered) KV cache.

    The cache stores roped keys with absolute positions in ``pos``
    (-1 = empty). With a sliding window the buffer length equals the
    window and insertion wraps.

    ``use_flash`` routes the attention through the split-KV Pallas
    kernel (``repro.kernels.flash_decode``). Valid only while the cache
    is a contiguous prefix (no ring wrap: cache_len < buffer length),
    which holds whenever the buffer is sized to max_seq_len — so the
    auto default enables it on TPU for the unwindowed path only.
    """
    B = x.shape[0]
    H, Hkv, hd = cfg.num_heads, cfg.kv_heads(), cfg.resolved_head_dim()
    G = H // Hkv
    L = cache["k"].shape[1]
    positions = jnp.broadcast_to(cache_len[None], (B,))[:, None]   # (B,1)
    q, k, v = _qkv(cfg, p, x, positions)
    slot = (cache_len % L).astype(jnp.int32)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    pos_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], positions.astype(jnp.int32), slot, axis=1
    )
    valid = pos_cache >= 0
    w = cfg.sliding_window if window is None else window
    if use_flash is None:
        use_flash = _flash_decode_default() and not w
    if use_flash:
        from repro.kernels.ops import gqa_flash_decode

        out = gqa_flash_decode(
            q, k_cache, v_cache, kv_len=cache_len + 1, q_pos=cache_len,
            window=w or 0,
        ).reshape(B, 1, Hkv, G, hd)
    else:
        qg = q.reshape(B, 1, Hkv, G, hd)
        out = scaled_attention(
            qg, k_cache, v_cache,
            q_pos=positions, kv_pos=pos_cache, kv_valid=valid, causal=True,
            window=w,
        )
    y = jnp.einsum("bshgd,hgdk->bsk", out,
                   p["wo"].reshape(Hkv, G, hd, cfg.d_model))
    return y, {"k": k_cache, "v": v_cache, "pos": pos_cache}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------


def mla_params(cfg: ModelConfig, model_axis: int) -> Dict:
    m = cfg.mla
    H = cfg.num_heads
    mh = maybe_model(H, model_axis)
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    p = {
        "wkv_a": ParamSpec((cfg.d_model, m.kv_lora_rank + m.qk_rope_head_dim), P(None, None)),
        "kv_norm": norm_params(cfg, m.kv_lora_rank),
        "wkv_b_k": ParamSpec((m.kv_lora_rank, H, m.qk_nope_head_dim), P(None, mh, None)),
        "wkv_b_v": ParamSpec((m.kv_lora_rank, H, m.v_head_dim), P(None, mh, None)),
        "wo": ParamSpec((H, m.v_head_dim, cfg.d_model), P(mh, None, None)),
    }
    if m.q_lora_rank > 0:
        p["wq_a"] = ParamSpec((cfg.d_model, m.q_lora_rank), P(None, None))
        p["q_norm"] = norm_params(cfg, m.q_lora_rank)
        p["wq_b"] = ParamSpec((m.q_lora_rank, H, qk), P(None, mh, None))
    else:
        p["wq"] = ParamSpec((cfg.d_model, H, qk), P(None, mh, None))
    return p


def _mla_q(cfg: ModelConfig, p, x, positions):
    m = cfg.mla
    if m.q_lora_rank > 0:
        cq = apply_norm(cfg, p["q_norm"], x @ p["wq_a"])
        q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim :], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(cfg: ModelConfig, p, x, positions):
    m = cfg.mla
    kv = x @ p["wkv_a"]
    c_kv = apply_norm(cfg, p["kv_norm"], kv[..., : m.kv_lora_rank])
    k_rope = kv[..., m.kv_lora_rank :][:, :, None, :]              # (B,S,1,rd)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_forward(cfg: ModelConfig, p, x, positions, *, causal=True):
    """Training/prefill MLA. Decompresses K/V per head (standard form)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    c_kv, k_rope = _mla_latent(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wkv_b_k"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wkv_b_v"])
    scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    # combine nope + rope score parts by concatenating feature dims
    q_full = jnp.concatenate(
        [q_nope, q_rope], axis=-1
    )                                                              # (B,S,H,qk)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, m.qk_rope_head_dim))],
        axis=-1,
    )
    qg = q_full[:, :, :, None, :]                                  # G=1 over H kv-heads
    out = scaled_attention(
        qg, k_full, v, q_pos=positions, kv_pos=positions, causal=causal,
        window=cfg.sliding_window, scale=scale,
    )[:, :, :, 0, :]
    return jnp.einsum("bshv,hvd->bsd", out, p["wo"])


def mla_init_cache(cfg: ModelConfig, batch: int, length: int, dtype) -> Dict:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, length, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, length, m.qk_rope_head_dim), dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),
    }


def mla_decode(cfg: ModelConfig, p, x, cache, cache_len, *,
               use_flash: Optional[bool] = None):
    """Absorbed MLA decode: attention runs in the latent space, so the cache
    is only (L, kv_lora + rope_dim) — O(L) memory, the property that lets
    deepseek-v2 run long_500k without a sliding window.

    ``use_flash`` routes the latent attention through the split-KV
    Pallas kernel (``repro.kernels.mla_decode``); same contiguous-prefix
    requirement as ``gqa_decode`` (the MLA cache never windows, so any
    buffer sized to max_seq_len qualifies)."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    L = cache["c_kv"].shape[1]
    positions = jnp.broadcast_to(cache_len[None], (B,))[:, None]
    q_nope, q_rope = _mla_q(cfg, p, x, positions)                  # (B,1,H,*)
    c_new, kr_new = _mla_latent(cfg, p, x, positions)
    slot = (cache_len % L).astype(jnp.int32)
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_new, slot, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], kr_new, slot, axis=1)
    pos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], positions.astype(jnp.int32), slot, axis=1
    )
    # absorb wkv_b_k into the query: q_lat (B,1,H,kv_lora)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wkv_b_k"])
    scale = 1.0 / (m.qk_nope_head_dim + m.qk_rope_head_dim) ** 0.5
    if use_flash is None:
        use_flash = _flash_decode_default()
    if use_flash:
        from repro.kernels.ops import mla_flash_decode

        out_lat = mla_flash_decode(
            q_lat, q_rope, c_kv, k_rope, scale=scale,
            kv_len=cache_len + 1, q_pos=cache_len,
        )                                                          # (B,1,H,r)
    else:
        scores = (
            jnp.einsum("bshr,blr->bhsl", q_lat, c_kv)
            + jnp.einsum("bshk,blk->bhsl", q_rope, k_rope)
        ).astype(jnp.float32)
        valid = (pos >= 0) & (pos <= positions[:, :1])             # (B, L)
        scores = jnp.where(valid[:, None, None, :], scores * scale, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(c_kv.dtype)
        out_lat = jnp.einsum("bhsl,blr->bshr", probs, c_kv)        # (B,1,H,r)
    out = jnp.einsum("bshr,rhv->bshv", out_lat, p["wkv_b_v"])
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return y, {"c_kv": c_kv, "k_rope": k_rope, "pos": pos}


# ---------------------------------------------------------------------------
# Cross attention (encoder-decoder)
# ---------------------------------------------------------------------------


def cross_attention_params(cfg: ModelConfig, model_axis: int) -> Dict:
    return gqa_params(cfg, model_axis)


def cross_attention(cfg: ModelConfig, p, x, enc_kv, enc_valid=None):
    """x: (B,S,d); enc_kv: (k, v) each (B,Lenc,Hkv,hd) precomputed."""
    B, S, _ = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.kv_heads(), cfg.resolved_head_dim()
    G = H // Hkv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    k, v = enc_kv
    qg = q.reshape(B, S, Hkv, G, hd)
    q_pos = jnp.zeros((B, S), jnp.int32)
    kv_pos = jnp.zeros((B, k.shape[1]), jnp.int32)
    out = scaled_attention(
        qg, k, v, q_pos=q_pos, kv_pos=kv_pos, kv_valid=enc_valid,
        causal=False, window=0,
    )
    return jnp.einsum("bshgd,hgdk->bsk", out,
                      p["wo"].reshape(Hkv, G, hd, cfg.d_model))


def encode_cross_kv(cfg: ModelConfig, p, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return k, v
