"""Mixture-of-Experts block: top-k routing with capacity + scatter dispatch.

TPU adaptation: instead of the GShard one-hot dispatch einsum — whose
``(tokens, experts, capacity)`` one-hot tensor is prohibitively large at
DeepSeek/Kimi expert counts — we compute per-token expert slots with a
cumsum and dispatch with scatter-add into per-expert buffers that are
sharded over the ``model`` mesh axis (expert parallelism). The gather back
uses plain ``take``. Over-capacity tokens are dropped (their combine
weight contribution is zero), matching the capacity-factor semantics of
GShard/Switch.

Shared experts (DeepSeek/Kimi style) are a dense FFN applied to every
token, fused into one wide FFN of width ``num_shared * d_ff_expert``.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# jax >= 0.6 promotes shard_map to jax.shard_map (check_vma kwarg);
# earlier releases have it under jax.experimental with check_rep.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SHARD_MAP_NOCHECK = {"check_vma": False}
else:                                            # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_NOCHECK = {"check_rep": False}

from repro.config import ModelConfig
from repro.models.common import (
    ParamSpec,
    apply_ffn,
    constrain,
    ffn_params,
    maybe_model,
)


def moe_params(cfg: ModelConfig, model_axis: int, data_axis: int = 0) -> Dict:
    """Expert-parallel sharding: the expert dim shards over the DATA mesh
    axis and the per-expert hidden dim over the MODEL axis, so expert
    weights shard over the full 2-D mesh (kimi-k2's 2 TB of experts ->
    ~8 GB/chip on 16x16; with experts only on the model axis they were
    125 GB/chip — found by the dry-run)."""
    m = cfg.moe
    E, dff = m.num_experts, m.d_ff_expert
    me = "data" if data_axis and E % data_axis == 0 and E >= data_axis else None
    mf = maybe_model(dff, model_axis)
    p = {
        "router": ParamSpec((cfg.d_model, E), P(None, None), "small", dtype="float32"),
        "w_gate": ParamSpec((E, cfg.d_model, dff), P(me, None, mf)),
        "w_up": ParamSpec((E, cfg.d_model, dff), P(me, None, mf)),
        "w_down": ParamSpec((E, dff, cfg.d_model), P(me, mf, None)),
    }
    if m.num_shared_experts > 0:
        shared_ff = m.num_shared_experts * dff
        p["shared"] = ffn_params(cfg, cfg.d_model, shared_ff, model_axis)
    return p


def capacity(m, tokens: int) -> int:
    cap = int(math.ceil(tokens * m.top_k / m.num_experts * m.capacity_factor))
    return max(8, -(-cap // 8) * 8)  # round up to 8 lanes


def route(m, router_w, x_flat) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (topk_weights (T,k) f32, topk_ids (T,k) i32, aux_loss scalar)."""
    logits = (x_flat.astype(jnp.float32) @ router_w).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_ids = jax.lax.top_k(probs, m.top_k)
    topk_w = topk_p / jnp.maximum(topk_p.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing auxiliary loss
    E = logits.shape[-1]
    me = probs.mean(axis=0)                                        # mean prob per expert
    ce = jnp.zeros((E,), jnp.float32).at[topk_ids.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(ce.sum(), 1.0)
    aux = E * jnp.sum(me * ce) * m.router_aux_loss_weight
    return topk_w, topk_ids.astype(jnp.int32), aux


def moe_ffn(cfg: ModelConfig, p: Dict, x: jax.Array,
            mesh=None) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,d) -> (B,S,d), aux_loss.

    Two dispatch paths:
      * ``shard_map`` expert-parallel (production): local slot assignment
        per data shard, one all-to-all to the expert owners, expert FFN,
        psum over the model axis, reverse all-to-all. Chosen when a mesh
        is provided and the batch/expert dims divide it. (The GSPMD
        scatter path all-gathered the full (T*topk, D) dispatch tensor —
        14.4 TB/device/step on kimi-k2 prefill; found by the dry-run.)
      * dense scatter (CPU smoke tests / decode's tiny T): below.
    """
    m = cfg.moe
    B, S, D = x.shape
    if mesh is not None:
        da = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        dp = 1
        for a in da:
            dp *= mesh.shape[a]
        ep = mesh.shape.get("data", 1)       # experts shard over 'data'
        if (dp > 1 and B % dp == 0 and m.num_experts % ep == 0
                and "model" in mesh.axis_names):
            return _moe_ffn_expert_parallel(cfg, p, x, mesh, da)
    return _moe_ffn_dense(cfg, p, x)


def _moe_ffn_dense(cfg: ModelConfig, p: Dict, x: jax.Array):
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E = m.num_experts
    cap = capacity(m, T)
    xf = x.reshape(T, D)

    topk_w, topk_ids, aux = route(m, p["router"], xf)

    # slot assignment: position of each (token, k) within its expert queue
    flat_ids = topk_ids.reshape(-1)                                # (T*k,)
    oh = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)              # (T*k, E)
    pos_in_expert = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1      # (T*k,)
    in_cap = pos_in_expert < cap
    slot = jnp.where(in_cap, flat_ids * cap + pos_in_expert, E * cap)

    # dispatch: scatter tokens into (E*cap, D) buffers (row E*cap = drop bin)
    src = jnp.repeat(xf, m.top_k, axis=0)                          # (T*k, D)
    buf = jnp.zeros((E * cap + 1, D), x.dtype).at[slot].set(src, mode="drop")
    buf = buf[: E * cap].reshape(E, cap, D)
    # expert-parallel layout: experts over the data axis (matches the
    # expert-weight sharding; the dispatch scatter becomes an all-to-all)
    buf = constrain(buf, P("data", None, None))

    # expert FFN (einsum over expert-sharded weights)
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out = jnp.einsum("ecf,efd->ecd", gate * up, p["w_down"])       # (E,cap,D)
    out = constrain(out, P("data", None, None))

    # combine: gather each (token, k) result and weight it
    outf = out.reshape(E * cap, D)
    gathered = jnp.take(outf, jnp.minimum(slot, E * cap - 1), axis=0)
    w = (topk_w.reshape(-1) * in_cap.astype(jnp.float32)).astype(x.dtype)
    y = (gathered * w[:, None]).reshape(T, m.top_k, D).sum(axis=1)

    if m.num_shared_experts > 0:
        y = y + apply_ffn(cfg, p["shared"], xf)
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# Expert-parallel dispatch (shard_map)
# ---------------------------------------------------------------------------


def _sorted_slots(flat_ids: jax.Array, E: int, cap: int):
    """Sort-based slot assignment: position of each (token, k) within its
    expert's queue, O(Tk log Tk) memory O(Tk) — replaces the (Tk, E)
    one-hot cumsum (which is 800 MB/device at kimi-k2 prefill scale)."""
    Tk = flat_ids.shape[0]
    order = jnp.argsort(flat_ids)
    sorted_ids = flat_ids[order]
    run_start = jnp.searchsorted(sorted_ids, jnp.arange(E)).astype(jnp.int32)
    pos_sorted = jnp.arange(Tk, dtype=jnp.int32) - run_start[sorted_ids]
    pos = jnp.zeros((Tk,), jnp.int32).at[order].set(pos_sorted)
    in_cap = pos < cap
    slot = jnp.where(in_cap, flat_ids * cap + pos, E * cap)
    return slot, in_cap


def _moe_ffn_expert_parallel(cfg: ModelConfig, p: Dict, x: jax.Array,
                             mesh, data_axes):
    """shard_map expert parallelism.

    Layout: tokens shard over the data axes; experts shard over 'data'
    (replicated across 'pod': each pod serves its own tokens); the
    per-expert hidden dim shards over 'model'.

    Per layer collectives (the roofline's collective term):
      all-to-all (tokens -> expert owners)     T_l * topk * D bytes
      psum over model (down-proj partial sums) E_l * cap' * D bytes
      all-to-all (results -> token owners)     T_l * topk * D bytes
    """
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    B, S, D = x.shape
    E = m.num_experts
    ep = mesh.shape["data"]
    batch_entry = data_axes if len(data_axes) > 1 else data_axes[0]

    def local_fn(xl, router_w, wg, wu, wd):
        Bl, Sl, _ = xl.shape
        T_l = Bl * Sl
        xf = xl.reshape(T_l, D)
        cap_l = capacity(m, T_l)
        topk_w, topk_ids, aux = route(m, router_w, xf)
        flat_ids = topk_ids.reshape(-1)
        slot, in_cap = _sorted_slots(flat_ids, E, cap_l)
        src = jnp.repeat(xf, m.top_k, axis=0)
        buf = jnp.zeros((E * cap_l + 1, D), xf.dtype).at[slot].set(
            src, mode="drop")
        buf = buf[: E * cap_l].reshape(E, cap_l, D)
        # exchange: every data shard sends each expert-owner its slice
        buf = jax.lax.all_to_all(buf, "data", split_axis=0, concat_axis=1,
                                 tiled=True)        # (E/ep, ep*cap_l, D)
        gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
        up = jnp.einsum("ecd,edf->ecf", buf, wu)
        out = jnp.einsum("ecf,efd->ecd", gate * up, wd)
        out = jax.lax.psum(out, "model")            # dff partial sums
        out = jax.lax.all_to_all(out, "data", split_axis=1, concat_axis=0,
                                 tiled=True)        # (E, cap_l, D)
        outf = out.reshape(E * cap_l, D)
        gathered = jnp.take(outf, jnp.minimum(slot, E * cap_l - 1), axis=0)
        w = (topk_w.reshape(-1) * in_cap.astype(jnp.float32)).astype(
            xf.dtype)
        y = (gathered * w[:, None]).reshape(T_l, m.top_k, D).sum(axis=1)
        aux = jax.lax.pmean(aux, data_axes)
        return y.reshape(Bl, Sl, D), aux

    y, aux = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(batch_entry, None, None),             # x
            P(None, None),                          # router
            P("data", None, "model"),               # w_gate
            P("data", None, "model"),               # w_up
            P("data", "model", None),               # w_down
        ),
        out_specs=(P(batch_entry, None, None), P()),
        **_SHARD_MAP_NOCHECK,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    if m.num_shared_experts > 0:
        y = y + apply_ffn(cfg, p["shared"], x.reshape(B * S, D)).reshape(
            B, S, D)
    return y, aux
