"""Config system for repro.

Dataclass-based, layered: ModelConfig (architecture), TuneConfig (LPT
algorithm hyperparams), MeshConfig (distribution), RunConfig (driver).
Every assigned architecture registers a ModelConfig factory in
``repro.configs`` under its ``--arch`` id.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration (GShard-style dispatch)."""
    num_experts: int = 0                 # routed experts; 0 => dense FFN
    top_k: int = 2
    num_shared_experts: int = 0          # always-on experts (DeepSeek-style)
    d_ff_expert: int = 0                 # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_loss_weight: float = 0.001
    # first N layers use a dense FFN instead of MoE (DeepSeek/Kimi style)
    first_dense_layers: int = 1


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """State-space / linear-attention configuration."""
    kind: str = "rwkv6"                  # "rwkv6" | "mamba2"
    state_size: int = 64                 # per-head state dim (rwkv head dim / mamba d_state)
    num_heads: int = 0                   # 0 => derived d_model // state_size
    chunk_size: int = 128                # chunked-scan block length
    expand: int = 2                      # mamba2 inner expansion
    conv_width: int = 4                  # mamba2 short conv


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: SSM backbone + shared attention block."""
    attn_every: int = 6                  # apply the shared attention block every N ssm layers
    shared_attn: bool = True             # single shared parameter set for all applications


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder (Seamless-M4T style)."""
    num_encoder_layers: int = 12
    encoder_seq_len: int = 1024          # precomputed frame/patch embedding length
    cross_attention: bool = True


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB: provides precomputed embeddings of the right
    shape (mel+conv for audio; ViT patches for VLM). Per task spec the
    frontend itself is not implemented — only its output interface."""
    kind: str = "none"                   # "none" | "audio" | "vision"
    num_embeddings: int = 0              # patches / frames prepended to text
    embed_dim: int = 0                   # frontend output dim (projected to d_model)


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    arch_type: str = "dense"             # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""                     # citation bracket from the assignment

    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                    # 0 => d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    max_seq_len: int = 4096

    # attention family: "gqa" | "mla" | "none" (attention-free)
    attention: str = "gqa"
    mla: Optional[MLAConfig] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0              # 0 => full attention
    # activation: "swiglu" | "gelu"
    activation: str = "swiglu"
    norm: str = "rmsnorm"                # "rmsnorm" | "layernorm"
    parallel_block: bool = False         # command-r style parallel attn+ffn
    tie_embeddings: bool = True
    logit_soft_cap: float = 0.0

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)

    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True                   # checkpoint each block in training
    # shard activations over (batch x SEQUENCE) instead of batch-only:
    # context parallelism for archs whose head counts don't divide the
    # model axis (phi3: 40 heads vs 16-way mesh -> replicated attention)
    seq_shard: bool = False

    def kv_heads(self) -> int:
        return self.num_kv_heads or self.num_heads

    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Prompt-tuning / job / distribution / run configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TuneConfig:
    """LPT algorithm hyperparameters (Table 3 'Hyperparam')."""
    algorithm: str = "soft_prompt"       # "soft_prompt" | "prefix"
    prompt_len: int = 16                 # tunable virtual tokens
    lr: float = 0.3
    weight_decay: float = 0.0
    optimizer: str = "adam"
    batch_size: int = 8
    max_iters: int = 400
    eval_every: int = 10
    eval_samples: int = 16               # Eqn-1 evaluation set size
    seed: int = 0


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")
    multi_pod: bool = False

    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axis_names if a in ("pod", "data"))


@dataclass(frozen=True)
class InputShape:
    """Assigned input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                            # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class RunConfig:
    arch: str = "gpt2-base"
    shape: str = "train_4k"
    steps: int = 100
    microbatches: int = 1                # grad-accumulation factor
    log_every: int = 10
    checkpoint_dir: str = ""
    seed: int = 0
