from repro.config.base import (
    EncDecConfig,
    FrontendConfig,
    HybridConfig,
    INPUT_SHAPES,
    InputShape,
    MLAConfig,
    MeshConfig,
    ModelConfig,
    MoEConfig,
    RunConfig,
    SSMConfig,
    TuneConfig,
)

__all__ = [
    "EncDecConfig",
    "FrontendConfig",
    "HybridConfig",
    "INPUT_SHAPES",
    "InputShape",
    "MLAConfig",
    "MeshConfig",
    "ModelConfig",
    "MoEConfig",
    "RunConfig",
    "SSMConfig",
    "TuneConfig",
]
