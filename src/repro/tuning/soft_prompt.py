"""Soft prompt tuning [Lester et al. '21] / prefix-style reparameterized
variant [Li & Liang '21] — the LPT algorithms the paper schedules.

The tunable object is a continuous prompt ``(P, d_model)`` prepended to
the embedded input. Model weights stay FROZEN: gradients are taken w.r.t.
the prompt parameters only, which is why LPT's cross-GPU gradient payload
is tiny (paper §2.2: 0.4-0.5% comm overhead).

``PromptTuner`` also implements Eqn 1's ``score`` (mean eval loss of a
candidate prompt WITHOUT tuning) used by the Prompt Bank, and the
``activation_features`` extractor used for bank clustering.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, TuneConfig
from repro.data import TaskLoader, batch_to_jnp
from repro.models import Model
from repro.train import apply_updates, lpt_loss, make_optimizer


def init_prompt_from_tokens(model: Model, params, token_ids: jax.Array):
    """Initialize the soft prompt from token embeddings (the 'initial
    prompt' a user provides as text; Fig 1 step 1)."""
    emb = jnp.take(params["embedding"], token_ids, axis=0)
    return {"soft_prompt": emb.astype(jnp.float32)}


def init_prompt_random(model: Model, prompt_len: int, key: jax.Array):
    d = model.cfg.d_model
    scale = 0.5 / np.sqrt(d)
    return {
        "soft_prompt": jax.random.normal(key, (prompt_len, d), jnp.float32) * scale
    }


@dataclass
class PromptTuner:
    model: Model
    tune_cfg: TuneConfig

    def __post_init__(self):
        self.optimizer = make_optimizer(
            self.tune_cfg.optimizer, self.tune_cfg.lr, self.tune_cfg.weight_decay
        )
        model = self.model
        P = self.tune_cfg.prompt_len

        def loss_fn(prompt_params, params, batch):
            prompt = self._materialize_prompt(prompt_params, params)
            return lpt_loss(model, params, prompt, batch, P)

        self._loss = loss_fn
        self._grad = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))
        self._score = jax.jit(loss_fn)

        def step(prompt_params, opt_state, params, batch):
            (tot, (loss, _)), grads = self._grad(prompt_params, params, batch)
            updates, opt_state = self.optimizer.update(grads, opt_state, prompt_params)
            prompt_params = apply_updates(prompt_params, updates)
            return prompt_params, opt_state, loss

        self._step = jax.jit(step)

    # prefix variant: reparameterize the prompt through a small MLP
    def _materialize_prompt(self, prompt_params, params):
        sp = prompt_params["soft_prompt"]
        if self.tune_cfg.algorithm == "prefix" and "reparam_w" in prompt_params:
            h = jnp.tanh(sp @ prompt_params["reparam_w"])
            sp = sp + h @ prompt_params["reparam_v"]
        return sp

    def init_prompt(self, params, key: jax.Array, token_ids=None):
        if token_ids is not None:
            pp = init_prompt_from_tokens(self.model, params, token_ids)
        else:
            pp = init_prompt_random(self.model, self.tune_cfg.prompt_len, key)
        if self.tune_cfg.algorithm == "prefix":
            d = self.model.cfg.d_model
            k1, k2 = jax.random.split(key)
            r = max(d // 4, 8)
            pp["reparam_w"] = jax.random.normal(k1, (d, r), jnp.float32) * 0.02
            pp["reparam_v"] = jax.random.normal(k2, (r, d), jnp.float32) * 0.02
        return pp

    def init_opt(self, prompt_params):
        return self.optimizer.init(prompt_params)

    def step(self, prompt_params, opt_state, params, batch):
        return self._step(prompt_params, opt_state, params, batch_to_jnp(batch))

    def score(self, prompt_params, params, eval_batch) -> float:
        """Eqn 1: mean loss on D_eval, no tuning. Smaller is better."""
        tot, (loss, _) = self._score(prompt_params, params, batch_to_jnp(eval_batch))
        return float(loss)

    def evaluate(self, prompt_params, params, eval_batch) -> float:
        return self.score(prompt_params, params, eval_batch)

    # ------------------------------------------------------------------
    def tune(
        self,
        params,
        loader: TaskLoader,
        prompt_params,
        *,
        target_loss: Optional[float] = None,
        max_iters: Optional[int] = None,
        eval_every: Optional[int] = None,
    ) -> Dict[str, Any]:
        """Run LPT until the termination condition (Table 3): accuracy
        target (here: eval-loss target) or max iterations.

        Returns {prompt, iters, reached, history}."""
        max_iters = max_iters or self.tune_cfg.max_iters
        eval_every = eval_every or self.tune_cfg.eval_every
        eval_batch = loader.eval_batch(self.tune_cfg.eval_samples)
        opt_state = self.init_opt(prompt_params)
        history = []
        reached = False
        it = 0
        # the initial prompt may already meet the target (ITA = 0) — the
        # whole point of prompt reusing
        if target_loss is not None:
            ev0 = self.score(prompt_params, params, eval_batch)
            history.append((0, float("nan"), ev0))
            if ev0 <= target_loss:
                return {"prompt": prompt_params, "iters": 0,
                        "reached": True, "history": history}
        for it in range(1, max_iters + 1):
            batch = next(loader)
            prompt_params, opt_state, loss = self.step(
                prompt_params, opt_state, params, batch
            )
            if it % eval_every == 0:
                ev = self.score(prompt_params, params, eval_batch)
                history.append((it, float(loss), ev))
                if target_loss is not None and ev <= target_loss:
                    reached = True
                    break
        return {
            "prompt": prompt_params,
            "iters": it,
            "reached": reached,
            "history": history,
        }


def _probe_tokens(model: Model, n_probe: int, length: int) -> jax.Array:
    """Fixed probe inputs shared by all feature extractions (so features
    of different prompts are comparable)."""
    key = jax.random.key(20240517)
    lo, hi = 3, model.cfg.vocab_size // 2 + 3
    return jax.random.randint(key, (n_probe, length), lo, hi)


def activation_features(
    model: Model, params, prompt: jax.Array, *, n_probe: int = 4,
    probe_len: int = 9,
) -> np.ndarray:
    """Prompt Bank clustering feature (§4.3.1 'activation features').

    The LLM runs on ``[prompt, probe tokens]`` for a handful of FIXED
    probe inputs; the feature is the concatenated final-position hidden
    state per probe — i.e. the model's prediction state under this
    prompt, which directly encodes the behaviour the prompt induces.
    (Pooling over a dummy input alone clusters by prompt norm, not by
    task — measured: family-mixed clusters and 20x worse two-layer
    lookups.)"""
    if prompt.ndim == 2:
        prompt = prompt[None]
    B, P, d = prompt.shape
    probes = _probe_tokens(model, n_probe, probe_len)     # (n, L)
    n, L = probes.shape
    tokens = jnp.broadcast_to(probes[None], (B, n, L)).reshape(B * n, L)
    prompt_rep = jnp.repeat(prompt, n, axis=0)            # (B*n, P, d)
    frontend = None
    if model.cfg.frontend.kind != "none":
        frontend = jnp.zeros(
            (B * n, model.cfg.frontend.num_embeddings,
             model.cfg.frontend.embed_dim),
            jnp.float32,
        )
    hidden, _ = model.backbone(params, tokens, prompt=prompt_rep,
                               frontend=frontend)
    feat = hidden[:, -1].astype(jnp.float32)              # prediction state
    feat = feat.reshape(B, n * feat.shape[-1])
    feat = feat / (jnp.linalg.norm(feat, axis=-1, keepdims=True) + 1e-8)
    return np.asarray(feat[0] if B == 1 else feat)
