from repro.tuning.soft_prompt import (
    PromptTuner,
    activation_features,
    init_prompt_from_tokens,
    init_prompt_random,
)

__all__ = [
    "PromptTuner",
    "activation_features",
    "init_prompt_from_tokens",
    "init_prompt_random",
]
