"""PromptTunerService — the single front door tying the paper's pieces
together: Prompt Bank (§4.3) + latency-budget routing (§4.4.3) +
Workload Scheduler (§4.4) + online bank insertion (Fig 5b), now served
from a multi-tenant sharded :class:`~repro.cluster.fabric.ClusterFabric`.

    service = PromptTunerService(SimConfig(max_gpus=32), bank=bank,
                                 score_fn_factory=my_scorer)
    handle = service.submit(SubmitRequest(task_id="t0", llm="gpt2-base",
                                          slo=120.0, iters_manual=400,
                                          iters_bank=120,
                                          tenant="acme",
                                          slo_class="premium"))
    service.stream(print)                # typed EngineEvent callbacks
    results = service.run_until_idle()
    service.summary_by_tenant()          # per-tenant SLO + billing

Per request the service:

1. resolves the tenant's service class (SLO multiplier / price tier /
   admission priority) and applies the class stringency to the SLO;
2. applies the §4.4.3 latency budget — the request is routed through the
   Prompt Bank only if the bank's lookup latency fits in
   ``latency_budget_frac`` of its effective SLO;
3. if routed (and a bank + scorer are attached), performs the two-layer
   lookup to pick the initial prompt, recording its origin and Eqn-1
   score on the handle;
4. places the job on a fabric shard and hands it to that shard's
   scheduling policy (any registry name — the facade is policy-agnostic);
5. on completion, inserts the freshly tuned prompt into the bank by
   feature similarity — no score evaluations (Fig 5b) — so later
   requests benefit from this request's tuning work.

The scorer is a factory ``score_fn_factory(request) -> (entry -> float)``
because Eqn-1 scores are computed against the *request's* eval set; the
bank itself stays agnostic to how scores are produced.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Set, Union

import numpy as np

from repro.cluster.engine import (
    ClusterEngine,
    EngineEvent,
    SimConfig,
    SimResult,
    bank_fits_budget,
)
from repro.cluster.elastic import ElasticConfig, TenantQuota
from repro.cluster.fabric import ClusterFabric
from repro.cluster.faults import FaultPlane
from repro.core.jobs import (
    DEFAULT_SLO_CLASS,
    LLM_PROFILES,
    SLO_CLASSES,
    Job,
    SLOClass,
)
from repro.core.prompt_bank import PromptBank, PromptEntry
from repro.obs import Telemetry

from repro.api.types import JobHandle, JobResult, SubmitRequest

ScoreFn = Callable[[PromptEntry], float]


class PromptTunerService:
    """Facade over fabric + policy + bank. ``policy`` is any registry
    name (``prompttuner`` by default), so baselines and new policies get
    the same front door for free. Pass a pre-built ``fabric`` to serve
    from several shards, or ``shards=``/``placement=`` to have the
    service build one; the default is a single-shard fabric, which is
    float-for-float identical to the pre-fabric engine.

    ``telemetry=True`` (or an un-attached :class:`repro.obs.Telemetry`)
    wires the fleet telemetry plane into the fabric: handles gain
    ``.timeline()``, and ``service.telemetry`` exposes the metrics
    registry, audit log, ``report()`` and trace exports. Recording rides
    the event stream only, so results are identical with it on or off."""

    def __init__(
        self,
        cfg: Optional[SimConfig] = None,
        *,
        policy: Optional[str] = None,
        bank: Optional[PromptBank] = None,
        score_fn_factory: Optional[Callable[[SubmitRequest], ScoreFn]] = None,
        fabric: Optional[ClusterFabric] = None,
        shards: Optional[int] = None,
        placement: Optional[str] = None,
        elastic: Optional[ElasticConfig] = None,
        faults: Optional[FaultPlane] = None,
        telemetry: Optional[Union[bool, Telemetry]] = None,
    ):
        if fabric is not None:
            conflicting = [name for name, given in [
                ("cfg", cfg), ("policy", policy), ("shards", shards),
                ("placement", placement), ("elastic", elastic),
                ("faults", faults),
            ] if given is not None]
            if conflicting:
                raise ValueError(
                    f"pass either fabric= or {conflicting} — a pre-built "
                    "fabric already fixes cfg/policy/shards/placement/"
                    "elastic/faults")
            self.fabric = fabric
            self.cfg = fabric.cfg
            self.policy_name = fabric.policy_name
        else:
            self.cfg = cfg or SimConfig()
            self.policy_name = policy or "prompttuner"
            self.fabric = ClusterFabric(
                self.cfg, self.policy_name, shards=shards or 1,
                placement=placement or "llm-affinity", elastic=elastic,
                faults=faults)
        if telemetry is None or telemetry is False:
            self.telemetry: Optional[Telemetry] = None
        else:
            self.telemetry = (Telemetry() if telemetry is True
                              else telemetry)
            if not self.telemetry.attached:
                self.telemetry.attach(self.fabric)
            elif self.telemetry._fabric is not self.fabric:
                raise ValueError(
                    "telemetry= is already attached to a different fabric; "
                    "use one Telemetry per fabric")
        self.bank = bank
        self.score_fn_factory = score_fn_factory
        self._handles: Dict[int, JobHandle] = {}
        self._requests: Dict[int, SubmitRequest] = {}
        self._reported: Set[int] = set()
        self._next_id = 0

    @property
    def engine(self) -> ClusterEngine:
        """The first fabric shard (back-compat with the pre-fabric,
        single-engine service surface)."""
        return self.fabric.shards[0]

    # -- service classes ---------------------------------------------------------

    @staticmethod
    def resolve_slo_class(slo_class) -> SLOClass:
        """None -> standard; a catalogue name -> its class; an SLOClass
        passes through."""
        if slo_class is None:
            return DEFAULT_SLO_CLASS
        if isinstance(slo_class, SLOClass):
            return slo_class
        try:
            return SLO_CLASSES[slo_class]
        except KeyError:
            raise KeyError(f"unknown SLO class {slo_class!r}; "
                           f"known: {sorted(SLO_CLASSES)}") from None

    # -- §4.4.3 latency budget -------------------------------------------------

    def route_through_bank(self, req: SubmitRequest) -> bool:
        """Would this request's bank lookup fit in its latency budget?
        (The same predicate the scheduler applies to the job — shared
        implementation, so handle and record can never disagree.)"""
        cls = self.resolve_slo_class(req.slo_class)
        return bank_fits_budget(
            self.cfg, LLM_PROFILES[req.llm].bank_lookup_s,
            req.slo * cls.slo_multiplier)

    # -- front door ------------------------------------------------------------

    def submit(self, req: SubmitRequest) -> JobHandle:
        """Admit one request: resolve its service class, route, look up
        an initial prompt if routed, and place the tuning job on a
        fabric shard for the next ``run_until_idle``."""
        if req.llm not in LLM_PROFILES:
            raise KeyError(f"unknown LLM {req.llm!r}; "
                           f"known: {sorted(LLM_PROFILES)}")
        cls = self.resolve_slo_class(req.slo_class)
        effective_slo = float(req.slo) * cls.slo_multiplier
        submitted_at = (self.fabric.now if req.submit_time is None
                        else float(req.submit_time))
        routed = self.route_through_bank(req)
        origin = score = init_prompt = None
        if routed and self.bank is not None and self.score_fn_factory is not None:
            lookup = self.bank.lookup(self.score_fn_factory(req))
            origin, score = lookup.entry.origin, lookup.score
            init_prompt = lookup.entry.prompt
        job_id = self._next_id
        self._next_id += 1
        job = Job(
            job_id=job_id,
            llm=req.llm,
            submit_time=submitted_at,
            slo=effective_slo,
            iters_manual=req.iters_manual,
            iters_bank=req.iters_bank,
            max_iters=req.max_iters,
            task_id=req.task_id,
            tenant=req.tenant,
            slo_class=cls,
        )
        shard = self.fabric.submit(job)
        rejected = shard < 0
        reason = self.fabric.rejections[-1][1] if rejected else None
        handle = JobHandle(
            job_id=job_id,
            task_id=req.task_id,
            llm=req.llm,
            submitted_at=submitted_at,
            routed_through_bank=routed,
            tenant=req.tenant,
            slo_class=cls.name,
            shard=shard,
            effective_slo=effective_slo,
            bank_origin=origin,
            bank_score=score,
            initial_prompt=init_prompt,
            rejected=rejected,
            reject_reason=reason,
            telemetry=self.telemetry,
        )
        if not rejected:
            self._handles[job_id] = handle
            self._requests[job_id] = req
        return handle

    def set_quota(self, tenant: str, quota: TenantQuota) -> None:
        """Attach/replace a tenant's admission quota. Requires an
        elastic fabric (``elastic=ElasticConfig(...)`` or a pre-built
        fabric with a controller)."""
        if self.fabric.controller is None:
            raise ValueError(
                "quotas need an elastic fabric: pass elastic=ElasticConfig() "
                "(or a fabric built with one)")
        self.fabric.controller.set_quota(tenant, quota)

    def run_until_idle(self) -> List[JobResult]:
        """Drive every fabric shard until no submitted work is
        outstanding. Returns a JobResult per job not yet reported,
        inserting freshly tuned prompts into the bank (Fig 5b) as their
        jobs finish."""
        self.fabric.run()
        out: List[JobResult] = []
        for rec in self.fabric.records:
            jid = rec.job.job_id
            if jid in self._reported or jid not in self._handles:
                continue
            self._reported.add(jid)
            req = self._requests[jid]
            inserted = False
            if (self.bank is not None and np.isfinite(rec.finish)
                    and req.prompt is not None and req.feature is not None):
                self.bank.insert(PromptEntry(
                    prompt=np.asarray(req.prompt),
                    feature=np.asarray(req.feature),
                    origin=f"{req.task_id}/online",
                ))
                inserted = True
            out.append(JobResult(
                handle=self._handles[jid],
                gpus=rec.gpus,
                start=rec.start,
                finish=rec.finish,
                violated=rec.violated,
                wait=rec.wait,
                used_bank=rec.used_bank,
                init_overhead=rec.init_overhead,
                inserted_to_bank=inserted,
                retries=rec.job.restarts,
            ))
        out.sort(key=lambda r: r.handle.job_id)
        return out

    # -- streaming ---------------------------------------------------------------

    def stream(self, cb: Callable[[EngineEvent], None]) -> None:
        """Subscribe ``cb`` to the fabric-wide event stream: one typed
        :class:`EngineEvent` per ARRIVAL / ROUND / JOB_DONE, in global
        simulated-time order, stamped with the originating shard."""
        self.fabric.on_event(cb)

    # -- introspection -----------------------------------------------------------

    @property
    def now(self) -> float:
        return self.fabric.now

    def sim_result(self) -> SimResult:
        """The merged fleet-wide SimResult so far — including
        ``util_samples`` and the per-tenant ledgers (nothing is dropped
        in the re-wrap)."""
        return self.fabric.result()

    def summary(self) -> Dict[str, float]:
        """Aggregate SLO/cost summary over everything run so far."""
        return self.sim_result().summary()

    def summary_by_tenant(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant jobs / SLO violations / billed cost / GPU-seconds
        over everything run so far."""
        return self.sim_result().summary_by_tenant()

    def report(self, **kw) -> str:
        """The telemetry plane's SLO-attainment / queue-depth time-series
        report (requires ``telemetry=``)."""
        if self.telemetry is None:
            raise ValueError("no telemetry recorded: construct the service "
                             "with telemetry=True (or a Telemetry instance)")
        return self.telemetry.report(**kw)

    def forensics_report(self):
        """Per-violation blame attribution rolled up fleet-wide — a
        :class:`repro.obs.forensics.ForensicsReport` answering *why*
        each violated/shed job missed its SLO (requires
        ``telemetry=``)."""
        if self.telemetry is None:
            raise ValueError("no telemetry recorded: construct the service "
                             "with telemetry=True (or a Telemetry instance)")
        return self.telemetry.forensics()
