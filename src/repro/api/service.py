"""PromptTunerService — the single front door tying the paper's pieces
together: Prompt Bank (§4.3) + latency-budget routing (§4.4.3) +
Workload Scheduler (§4.4) + online bank insertion (Fig 5b).

    service = PromptTunerService(SimConfig(max_gpus=32), bank=bank,
                                 score_fn_factory=my_scorer)
    handle = service.submit(SubmitRequest(task_id="t0", llm="gpt2-base",
                                          slo=120.0, iters_manual=400,
                                          iters_bank=120))
    results = service.run_until_idle()

Per request the service:

1. applies the §4.4.3 latency budget — the request is routed through the
   Prompt Bank only if the bank's lookup latency fits in
   ``latency_budget_frac`` of its SLO;
2. if routed (and a bank + scorer are attached), performs the two-layer
   lookup to pick the initial prompt, recording its origin and Eqn-1
   score on the handle;
3. hands the job to the scheduling policy (any registry name — the
   facade is policy-agnostic) over the event engine;
4. on completion, inserts the freshly tuned prompt into the bank by
   feature similarity — no score evaluations (Fig 5b) — so later
   requests benefit from this request's tuning work.

The scorer is a factory ``score_fn_factory(request) -> (entry -> float)``
because Eqn-1 scores are computed against the *request's* eval set; the
bank itself stays agnostic to how scores are produced.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Set

import numpy as np

from repro.cluster.engine import (
    ClusterEngine,
    SimConfig,
    SimResult,
    bank_fits_budget,
)
from repro.cluster.policies import get as get_policy
from repro.core.jobs import LLM_PROFILES, Job
from repro.core.prompt_bank import PromptBank, PromptEntry

from repro.api.types import JobHandle, JobResult, SubmitRequest

ScoreFn = Callable[[PromptEntry], float]


class PromptTunerService:
    """Facade over engine + policy + bank. ``policy`` is any registry
    name (``prompttuner`` by default), so baselines and new policies get
    the same front door for free."""

    def __init__(
        self,
        cfg: Optional[SimConfig] = None,
        *,
        policy: str = "prompttuner",
        bank: Optional[PromptBank] = None,
        score_fn_factory: Optional[Callable[[SubmitRequest], ScoreFn]] = None,
    ):
        self.cfg = cfg or SimConfig()
        self.policy_name = policy
        self.engine = ClusterEngine(self.cfg, get_policy(policy)(self.cfg))
        self.bank = bank
        self.score_fn_factory = score_fn_factory
        self._handles: Dict[int, JobHandle] = {}
        self._requests: Dict[int, SubmitRequest] = {}
        self._batch: List[Job] = []
        self._reported: Set[int] = set()
        self._next_id = 0

    # -- §4.4.3 latency budget -------------------------------------------------

    def route_through_bank(self, req: SubmitRequest) -> bool:
        """Would this request's bank lookup fit in its latency budget?
        (The same predicate the scheduler applies to the job — shared
        implementation, so handle and record can never disagree.)"""
        return bank_fits_budget(
            self.cfg, LLM_PROFILES[req.llm].bank_lookup_s, req.slo)

    # -- front door ------------------------------------------------------------

    def submit(self, req: SubmitRequest) -> JobHandle:
        """Admit one request: route, look up an initial prompt if routed,
        and enqueue the tuning job for the next ``run_until_idle``."""
        if req.llm not in LLM_PROFILES:
            raise KeyError(f"unknown LLM {req.llm!r}; "
                           f"known: {sorted(LLM_PROFILES)}")
        submitted_at = (self.engine.now if req.submit_time is None
                        else float(req.submit_time))
        routed = self.route_through_bank(req)
        origin = score = init_prompt = None
        if routed and self.bank is not None and self.score_fn_factory is not None:
            lookup = self.bank.lookup(self.score_fn_factory(req))
            origin, score = lookup.entry.origin, lookup.score
            init_prompt = lookup.entry.prompt
        job_id = self._next_id
        self._next_id += 1
        job = Job(
            job_id=job_id,
            llm=req.llm,
            submit_time=submitted_at,
            slo=float(req.slo),
            iters_manual=req.iters_manual,
            iters_bank=req.iters_bank,
            max_iters=req.max_iters,
            task_id=req.task_id,
        )
        handle = JobHandle(
            job_id=job_id,
            task_id=req.task_id,
            llm=req.llm,
            submitted_at=submitted_at,
            routed_through_bank=routed,
            bank_origin=origin,
            bank_score=score,
            initial_prompt=init_prompt,
        )
        self._handles[job_id] = handle
        self._requests[job_id] = req
        self._batch.append(job)
        return handle

    def run_until_idle(self) -> List[JobResult]:
        """Drive the engine until no submitted work is outstanding.
        Returns a JobResult per job not yet reported, inserting freshly
        tuned prompts into the bank (Fig 5b) as their jobs finish."""
        self.engine.run(self._batch)
        self._batch = []
        out: List[JobResult] = []
        for rec in self.engine.records:
            jid = rec.job.job_id
            if jid in self._reported or jid not in self._handles:
                continue
            self._reported.add(jid)
            req = self._requests[jid]
            inserted = False
            if (self.bank is not None and np.isfinite(rec.finish)
                    and req.prompt is not None and req.feature is not None):
                self.bank.insert(PromptEntry(
                    prompt=np.asarray(req.prompt),
                    feature=np.asarray(req.feature),
                    origin=f"{req.task_id}/online",
                ))
                inserted = True
            out.append(JobResult(
                handle=self._handles[jid],
                gpus=rec.gpus,
                start=rec.start,
                finish=rec.finish,
                violated=rec.violated,
                wait=rec.wait,
                used_bank=rec.used_bank,
                init_overhead=rec.init_overhead,
                inserted_to_bank=inserted,
            ))
        return out

    # -- introspection -----------------------------------------------------------

    @property
    def now(self) -> float:
        return self.engine.now

    def summary(self) -> Dict[str, float]:
        """Aggregate SLO/cost summary over everything run so far."""
        return SimResult(
            records=self.engine.records,
            cost=self.engine.cost,
            gpu_seconds=self.engine.gpu_seconds,
            makespan=self.engine.now,
        ).summary()
