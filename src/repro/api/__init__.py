"""repro.api — the service layer tying bank + tuner + scheduler into the
system the paper describes. See :class:`PromptTunerService`."""
from repro.api.service import PromptTunerService
from repro.api.types import JobHandle, JobResult, SubmitRequest

__all__ = [
    "JobHandle",
    "JobResult",
    "PromptTunerService",
    "SubmitRequest",
]
