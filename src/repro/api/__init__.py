"""repro.api — the service layer tying bank + tuner + scheduler into the
system the paper describes. See :class:`PromptTunerService`."""
from repro.api.service import PromptTunerService
from repro.api.types import JobHandle, JobResult, SubmitRequest
from repro.cluster.engine import EngineEvent
from repro.cluster.fabric import ClusterFabric
from repro.core.jobs import SLO_CLASSES, SLOClass

__all__ = [
    "ClusterFabric",
    "EngineEvent",
    "JobHandle",
    "JobResult",
    "PromptTunerService",
    "SLOClass",
    "SLO_CLASSES",
    "SubmitRequest",
]
