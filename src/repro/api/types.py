"""Typed request/handle/result surface of the PromptTuner service."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Union

import numpy as np

from repro.core.jobs import DEFAULT_TENANT, SLOClass


@dataclass
class SubmitRequest:
    """One LPT request as a user of the service states it (Table 3).

    ``iters_manual`` / ``iters_bank`` are the iterations-to-accuracy with
    the user's manual initial prompt vs. a bank-provided one (in the full
    testbed they come out of real tuning runs; the trace generator
    synthesizes them from the calibration distributions).

    ``tenant`` / ``slo_class`` identify who submitted and which service
    class they bought: the class's SLO multiplier scales ``slo`` (premium
    tightens, best-effort relaxes), its priority orders admission, and
    its price tier scales the tenant's billing ledger. ``slo_class``
    accepts a catalogue name (``premium`` / ``standard`` /
    ``best-effort``) or an :class:`~repro.core.jobs.SLOClass`; omitted
    means the standard single-tenant behaviour, unchanged.

    ``prompt`` / ``feature`` optionally carry the freshly tuned soft
    prompt and its activation feature; when present, the service inserts
    the prompt into the bank once the job finishes — the online insertion
    loop of Fig 5b.
    """

    task_id: str
    llm: str
    slo: float                         # seconds from submission
    iters_manual: int
    iters_bank: int
    submit_time: Optional[float] = None    # None => service clock "now"
    max_iters: int = 10_000
    tenant: str = DEFAULT_TENANT
    slo_class: Optional[Union[str, SLOClass]] = None
    prompt: Optional[np.ndarray] = None
    feature: Optional[np.ndarray] = None


@dataclass(frozen=True)
class JobHandle:
    """Returned by ``submit``: identity plus the routing decision.

    A submission bounced off a tenant quota comes back with
    ``rejected=True`` (and ``shard=-1``): the job was never placed,
    never runs, and never bills; ``reject_reason`` carries the quota
    dimension that tripped (GPU-second budget / cost cap / outstanding
    cap)."""

    job_id: int
    task_id: str
    llm: str
    submitted_at: float
    routed_through_bank: bool          # §4.4.3 latency-budget decision
    tenant: str = DEFAULT_TENANT
    slo_class: str = "standard"        # resolved service-class name
    shard: int = 0                     # fabric shard the job was placed on
    effective_slo: Optional[float] = None  # slo x class multiplier (s)
    bank_origin: Optional[str] = None  # origin of the looked-up initial prompt
    bank_score: Optional[float] = None # its Eqn-1 score
    initial_prompt: Optional[np.ndarray] = None  # the prompt itself, for tuning
    rejected: bool = False             # tenant quota bounced this submission
    reject_reason: Optional[str] = None
    # Attached by a telemetry-enabled service (repro.obs.Telemetry);
    # identity-only plumbing, excluded from equality/repr.
    telemetry: Optional[object] = field(default=None, repr=False,
                                        compare=False)

    def timeline(self):
        """This job's recorded lifecycle spans
        (:class:`~repro.obs.spans.JobTimeline`), available when the
        service was built with ``telemetry=``. Grows as events fold in;
        complete after ``run_until_idle``."""
        if self.telemetry is None:
            raise ValueError(
                "no telemetry recorded for this job: construct the service "
                "with telemetry=True (or a repro.obs.Telemetry instance)")
        return self.telemetry.timeline.timeline(self.job_id)


@dataclass(frozen=True)
class JobResult:
    """Returned by ``run_until_idle`` for each newly finished job."""

    handle: JobHandle
    gpus: int
    start: float
    finish: float
    violated: bool
    wait: float
    used_bank: bool
    init_overhead: float
    inserted_to_bank: bool             # Fig 5b online insertion happened
    retries: int = 0                   # crash-recovery re-placements

    @property
    def completed(self) -> bool:
        return np.isfinite(self.finish)
