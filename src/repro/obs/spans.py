"""Per-job lifecycle span timelines, folded from the fabric event stream.

A :class:`TimelineRecorder` subscribes through the existing ``on_event``
contract (``fabric.on_event(recorder.on_event)`` — or let
:class:`~repro.obs.Telemetry` wire it) and folds the typed event kinds
into one :class:`JobTimeline` per job:

* ``ARRIVAL`` opens the job's *queued* span on its placed shard;
* ``job_stolen`` closes the queued span on the donor and opens a fresh
  one on the receiving shard (a **shard hop**, kept in ``hops``);
* ``JOB_DONE`` finalizes: the engine stamps ``start_time`` /
  ``init_overhead`` / ``finish_time`` / ``gpus`` on the Job, so the
  closing fold splits the executed tail into an *init* span (allocation
  + instance warm-up + bank lookup) and a *running* span — yielding the
  full submitted → queued → init → running → done lifecycle without any
  extra engine instrumentation;
* ``job_rejected`` produces a zero-length *rejected* timeline carrying
  the quota reason.

The fault plane (:mod:`repro.cluster.faults`) adds three more kinds:

* ``job_orphaned`` closes whatever span was open as **truncated** — if
  the job had already started (the event fires before the fabric scrubs
  the runtime state), the fold derives truncated *init*/*running* spans
  up to the crash instant, so the trace shows exactly how much work the
  failure threw away;
* ``job_retried`` opens a fresh queued span on the retry shard and
  records the crash-driven move as a :class:`ShardHop`;
* ``job_shed`` closes the open span truncated and stamps the shed
  reason — the job's terminal state without a ``JOB_DONE``.

Spans are plain frozen dataclasses; the Chrome-trace / JSONL exporters
(:mod:`repro.obs.export`) consume them as-is. Jobs that never complete
(still pending when the run is cut off) keep their open queued span —
``end=None`` — which is itself diagnostic: that is *where* a violated
job spent its deadline. Call :meth:`TimelineRecorder.finalize` after a
run to close those stragglers as truncated spans at the horizon
instead of dropping them.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional

from repro.cluster.elastic import JOB_REJECTED, JOB_STOLEN
from repro.cluster.engine import ARRIVAL, JOB_DONE, EngineEvent
from repro.cluster.faults import JOB_ORPHANED, JOB_RETRIED, JOB_SHED

QUEUED, INIT, RUNNING, REJECTED = "queued", "init", "running", "rejected"


@dataclass(frozen=True)
class Span:
    """One closed (or still-open, ``end=None``) phase of a job's life on
    one shard."""

    job_id: int
    phase: str                 # queued | init | running | rejected
    shard: int
    start: float
    end: Optional[float]
    truncated: bool = False    # cut short by a fault / run horizon

    @property
    def duration(self) -> Optional[float]:
        return None if self.end is None else self.end - self.start


@dataclass(frozen=True)
class ShardHop:
    """One cross-shard move: the job left ``src`` for ``dst`` at
    ``time``. ``kind`` distinguishes an elastic steal/drain from a
    crash-driven retry re-placement — forensics blames them to
    different causes (``steal_hop`` vs ``crash_rework``)."""

    job_id: int
    time: float
    src: int
    dst: int
    kind: str = "steal"        # "steal" | "retry"


@dataclass
class JobTimeline:
    """Everything observed about one job, in span form."""

    job_id: int
    task_id: str
    llm: str
    tenant: str
    slo_class: str
    submit_time: float
    deadline: float
    spans: List[Span] = field(default_factory=list)
    hops: List[ShardHop] = field(default_factory=list)
    gpus: int = 0
    used_bank: bool = False
    violated: Optional[bool] = None     # None until JOB_DONE / rejection
    reject_reason: Optional[str] = None
    retries: int = 0                    # crash-driven re-placements
    shed_reason: Optional[str] = None   # set when the job was load-shed

    @property
    def shard(self) -> int:
        """Final shard (where the job ran, or last queued)."""
        return self.spans[-1].shard if self.spans else -1

    @property
    def done(self) -> bool:
        return self.violated is not None and self.reject_reason is None

    @property
    def finish(self) -> Optional[float]:
        for s in reversed(self.spans):
            if s.phase == RUNNING:
                return s.end
        return None

    def phase_seconds(self, phase: str) -> float:
        """Total closed-span seconds the job spent in ``phase``."""
        return sum(s.duration for s in self.spans
                   if s.phase == phase and s.end is not None)

    def to_dict(self) -> Dict:
        return {
            "type": "timeline",
            "job_id": self.job_id,
            "task_id": self.task_id,
            "llm": self.llm,
            "tenant": self.tenant,
            "slo_class": self.slo_class,
            "submit_time": self.submit_time,
            "deadline": self.deadline,
            "gpus": self.gpus,
            "used_bank": self.used_bank,
            "violated": self.violated,
            "reject_reason": self.reject_reason,
            "retries": self.retries,
            "shed_reason": self.shed_reason,
            "spans": [{"phase": s.phase, "shard": s.shard,
                       "start": s.start, "end": s.end,
                       "truncated": s.truncated}
                      for s in self.spans],
            "hops": [{"time": h.time, "src": h.src, "dst": h.dst,
                      "kind": h.kind}
                     for h in self.hops],
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "JobTimeline":
        tl = cls(
            job_id=int(d["job_id"]), task_id=d["task_id"], llm=d["llm"],
            tenant=d["tenant"], slo_class=d["slo_class"],
            submit_time=float(d["submit_time"]),
            deadline=float(d["deadline"]), gpus=int(d["gpus"]),
            used_bank=bool(d["used_bank"]), violated=d["violated"],
            reject_reason=d.get("reject_reason"),
            retries=int(d.get("retries", 0)),
            shed_reason=d.get("shed_reason"),
        )
        tl.spans = [Span(job_id=tl.job_id, phase=s["phase"],
                         shard=int(s["shard"]), start=float(s["start"]),
                         end=None if s["end"] is None else float(s["end"]),
                         truncated=bool(s.get("truncated", False)))
                    for s in d["spans"]]
        tl.hops = [ShardHop(job_id=tl.job_id, time=float(h["time"]),
                            src=int(h["src"]), dst=int(h["dst"]),
                            kind=h.get("kind", "steal"))
                   for h in d["hops"]]
        return tl


class TimelineRecorder:
    """Folds the fabric event stream into :class:`JobTimeline` objects.

    Stateless about the fabric beyond the events themselves — it can
    replay a recorded event list just as well as a live subscription
    (which is what the scripted-sequence tests do).
    """

    def __init__(self) -> None:
        self._timelines: Dict[int, JobTimeline] = {}

    # -- event folding -------------------------------------------------------

    def on_event(self, ev: EngineEvent) -> None:
        if ev.job is None:
            return                       # ROUND / SHARD_RESIZED: no job
        if ev.kind == ARRIVAL:
            self._on_arrival(ev)
        elif ev.kind == JOB_STOLEN:
            self._on_stolen(ev)
        elif ev.kind == JOB_DONE:
            self._on_done(ev)
        elif ev.kind == JOB_REJECTED:
            self._on_rejected(ev)
        elif ev.kind == JOB_ORPHANED:
            self._on_orphaned(ev)
        elif ev.kind == JOB_RETRIED:
            self._on_retried(ev)
        elif ev.kind == JOB_SHED:
            self._on_shed(ev)

    def _timeline_for(self, ev: EngineEvent) -> JobTimeline:
        job = ev.job
        tl = self._timelines.get(job.job_id)
        if tl is None:
            tl = JobTimeline(
                job_id=job.job_id, task_id=job.task_id, llm=job.llm,
                tenant=job.tenant, slo_class=job.slo_class.name,
                submit_time=job.submit_time, deadline=job.deadline)
            self._timelines[job.job_id] = tl
        return tl

    def _close_open_span(self, tl: JobTimeline, t: float,
                         truncated: bool = False) -> Optional[Span]:
        if tl.spans and tl.spans[-1].end is None:
            closed = replace(tl.spans[-1], end=t, truncated=truncated)
            tl.spans[-1] = closed
            return closed
        return None

    def _on_arrival(self, ev: EngineEvent) -> None:
        tl = self._timeline_for(ev)
        if tl.spans and tl.spans[-1].end is None:
            # steal re-admission: migrate() re-enqueues the job on the
            # receiver, whose engine emits a second ARRIVAL right after
            # the JOB_STOLEN fold already opened the receiver-side
            # queued span — not a new submission, nothing to add
            return
        tl.spans.append(Span(job_id=tl.job_id, phase=QUEUED, shard=ev.shard,
                             start=ev.time, end=None))

    def _on_stolen(self, ev: EngineEvent) -> None:
        # ev.shard is the RECEIVING shard (fabric contract); the donor is
        # wherever the open queued span lives.
        tl = self._timeline_for(ev)
        closed = self._close_open_span(tl, ev.time)
        src = closed.shard if closed is not None else -1
        tl.hops.append(ShardHop(job_id=tl.job_id, time=ev.time, src=src,
                                dst=ev.shard))
        tl.spans.append(Span(job_id=tl.job_id, phase=QUEUED, shard=ev.shard,
                             start=ev.time, end=None))

    def _on_done(self, ev: EngineEvent) -> None:
        job = ev.job
        tl = self._timeline_for(ev)
        start = job.start_time if job.start_time is not None else ev.time
        self._close_open_span(tl, start)
        init_end = min(start + job.init_overhead, ev.time)
        if init_end > start:
            tl.spans.append(Span(job_id=tl.job_id, phase=INIT,
                                 shard=ev.shard, start=start, end=init_end))
        tl.spans.append(Span(job_id=tl.job_id, phase=RUNNING, shard=ev.shard,
                             start=init_end, end=ev.time))
        tl.gpus = job.gpus
        tl.used_bank = job.used_bank
        tl.violated = ev.time > tl.deadline + 1e-9

    def _on_rejected(self, ev: EngineEvent) -> None:
        tl = self._timeline_for(ev)
        tl.spans.append(Span(job_id=tl.job_id, phase=REJECTED, shard=ev.shard,
                             start=ev.time, end=ev.time))
        tl.reject_reason = ev.detail or "rejected"
        tl.violated = None

    def _on_orphaned(self, ev: EngineEvent) -> None:
        # Fired before the fabric scrubs the job, so start_time /
        # init_overhead still describe the attempt the crash cut short.
        job = ev.job
        tl = self._timeline_for(ev)
        start = job.start_time
        if start is None:
            self._close_open_span(tl, ev.time, truncated=True)
            return
        self._close_open_span(tl, start)
        init_end = min(start + job.init_overhead, ev.time)
        if init_end > start:
            tl.spans.append(Span(job_id=tl.job_id, phase=INIT, shard=ev.shard,
                                 start=start, end=init_end, truncated=True))
        if ev.time > init_end:
            tl.spans.append(Span(job_id=tl.job_id, phase=RUNNING,
                                 shard=ev.shard, start=init_end, end=ev.time,
                                 truncated=True))

    def _on_retried(self, ev: EngineEvent) -> None:
        tl = self._timeline_for(ev)
        src = tl.spans[-1].shard if tl.spans else -1
        if src != ev.shard:
            tl.hops.append(ShardHop(job_id=tl.job_id, time=ev.time, src=src,
                                    dst=ev.shard, kind="retry"))
        tl.retries += 1
        tl.spans.append(Span(job_id=tl.job_id, phase=QUEUED, shard=ev.shard,
                             start=ev.time, end=None))

    def _on_shed(self, ev: EngineEvent) -> None:
        tl = self._timeline_for(ev)
        self._close_open_span(tl, ev.time, truncated=True)
        tl.shed_reason = ev.detail or "shed"
        tl.violated = True

    # -- finalization --------------------------------------------------------

    def finalize(self, horizon: Optional[float] = None) -> int:
        """Close every still-open span as **truncated** at ``horizon``
        (default: the latest timestamp seen anywhere in the recording).
        Jobs that never reached ``JOB_DONE`` — still queued when the run
        was cut off — end up with a closed, truncated span instead of
        being dropped by end-aware consumers. Returns the number of
        spans closed. Idempotent."""
        if horizon is None:
            horizon = 0.0
            for tl in self._timelines.values():
                for s in tl.spans:
                    horizon = max(horizon, s.start,
                                  s.end if s.end is not None else s.start)
        closed = 0
        for tl in self._timelines.values():
            if tl.spans and tl.spans[-1].end is None:
                t = max(horizon, tl.spans[-1].start)
                self._close_open_span(tl, t, truncated=True)
                closed += 1
        return closed

    # -- reads ---------------------------------------------------------------

    def timelines(self) -> Dict[int, JobTimeline]:
        return dict(self._timelines)

    def timeline(self, job_id: int) -> Optional[JobTimeline]:
        return self._timelines.get(job_id)

    def __len__(self) -> int:
        return len(self._timelines)

    def to_dicts(self) -> List[Dict]:
        return [tl.to_dict() for _, tl in sorted(self._timelines.items())]
