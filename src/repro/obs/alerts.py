"""Online alert rules: multi-window burn-rate, queue pressure,
quarantine count — evaluated in simulated time, emitted as typed
events.

:class:`AlertRules` subscribes to the fabric event stream (wired by
``Telemetry(alerts=...)``) and evaluates every rule at a fixed
sim-time cadence (``interval`` seconds, boundaries crossed by incoming
event times). A rule transition emits a typed
:data:`~repro.cluster.elastic.ALERT_FIRED` /
:data:`~repro.cluster.elastic.ALERT_RESOLVED` ``EngineEvent`` back
onto the same bus via ``fabric.announce`` — so the
:class:`~repro.cluster.elastic.ElasticController` (which schedules an
immediate control cycle on a firing) and any future SLO autotuner
subscribe with zero extra wiring, and telemetry folds the alert into
its audit log, metrics, and Chrome-trace instants automatically.

Rule kinds:

* ``burn_rate`` — the SRE multi-window burn rate on SLO attainment:
  ``burn(W) = violation_rate(W) / (1 - target_attainment)`` over the
  completions in the trailing window ``W``. Fires when **both** the
  long and short windows burn at ``threshold`` or above (the long
  window proves it matters, the short window proves it is still
  happening); resolves when the short window drops below.
* ``queue_pressure`` — max per-shard ``pressure`` gauge from the
  *captured, full* metrics windows, sustained at ``threshold`` or
  above for ``short_s`` seconds.
* ``quarantine`` — count of controller ``quarantine`` audit decisions
  in the trailing ``window_s`` seconds at ``threshold`` or above.

**Replay identity** (pinned by tests): :meth:`AlertRules.replay` re-
evaluates the same rules from an exported JSONL trace (timelines +
metric rows + audit entries) and fires at the *identical sim-times*
as the live run. Every input a rule reads is derived from data that
round-trips through the export: completion times/verdicts from the
timelines, pressure from the captured metric windows (full windows
only — live evaluation never sees the final partial window either),
quarantine decisions from the audit log.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.cluster.elastic import ALERT_FIRED, ALERT_RESOLVED, QUARANTINE
from repro.cluster.engine import JOB_DONE, EngineEvent

BURN_RATE = "burn_rate"
QUEUE_PRESSURE = "queue_pressure"
QUARANTINE_COUNT = "quarantine"
_KINDS = (BURN_RATE, QUEUE_PRESSURE, QUARANTINE_COUNT)

_EPS = 1e-9


@dataclass(frozen=True)
class AlertRule:
    """One alert rule. Which knobs apply depends on ``kind``:
    ``burn_rate`` reads ``long_s``/``short_s``/``target_attainment``;
    ``queue_pressure`` reads ``short_s`` (the sustain requirement);
    ``quarantine`` reads ``window_s``."""

    name: str
    kind: str
    threshold: float
    long_s: float = 300.0
    short_s: float = 60.0
    target_attainment: float = 0.90
    window_s: float = 600.0


DEFAULT_RULES: Tuple[AlertRule, ...] = (
    AlertRule(name="slo-burn", kind=BURN_RATE, threshold=2.0),
    AlertRule(name="queue-pressure", kind=QUEUE_PRESSURE, threshold=2.0),
    AlertRule(name="quarantine-count", kind=QUARANTINE_COUNT,
              threshold=1.0),
)


@dataclass(frozen=True)
class AlertEvent:
    """One fired/resolved transition, as recorded in ``history``."""

    time: float
    kind: str                  # alert_fired | alert_resolved
    rule: str
    value: float
    detail: str                # "<rule>: <why>" (matches the EngineEvent)


class AlertRules:
    """The online evaluator; one instance per fabric.

    Wire through ``Telemetry(alerts=AlertRules())`` — attach binds
    :meth:`bind` and subscribes :meth:`on_event` *after* telemetry's
    own subscription, so metric windows are captured before any rule
    reads them (the same visibility replay reconstructs).
    """

    def __init__(self, rules: Sequence[AlertRule] = DEFAULT_RULES, *,
                 interval: float = 15.0):
        if interval <= 0:
            raise ValueError(f"interval must be > 0 seconds, "
                             f"got {interval}")
        names = [r.name for r in rules]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate rule names: {names}")
        for r in rules:
            if r.kind not in _KINDS:
                raise ValueError(f"unknown rule kind {r.kind!r}; "
                                 f"expected one of {_KINDS}")
        self.rules = tuple(rules)
        self.interval = interval
        self.history: List[AlertEvent] = []
        self.active: Dict[str, bool] = {r.name: False for r in self.rules}
        self._above_since: Dict[str, Optional[float]] = {
            r.name: None for r in self.rules}
        self._completions: List[Tuple[float, bool]] = []
        self._next_eval = interval
        self._emit: Optional[Callable[[EngineEvent], None]] = None
        self._metrics = None
        self._audit = None
        self._full_width: Optional[float] = None
        self._replay_windows = None
        self._replay_audit = None

    # -- wiring ---------------------------------------------------------------

    def bind(self, *, emit: Optional[Callable[[EngineEvent], None]] = None,
             metrics=None, audit=None) -> "AlertRules":
        """Attach the event emitter (``fabric.announce``) and the
        telemetry stores the rules read. Done by ``Telemetry.attach``."""
        self._emit = emit
        self._metrics = metrics
        self._audit = audit
        if metrics is not None:
            self._full_width = metrics.window
        return self

    # -- live driving ---------------------------------------------------------

    def on_event(self, ev: EngineEvent) -> None:
        if ev.kind in (ALERT_FIRED, ALERT_RESOLVED):
            return                 # our own emissions re-enter the bus
        if ev.kind == JOB_DONE and ev.job is not None:
            self._completions.append(
                (ev.time, ev.time > ev.job.deadline + _EPS))
        while self._next_eval <= ev.time:
            self._evaluate(self._next_eval)
            self._next_eval += self.interval

    # -- rule evaluation (shared by live + replay) ----------------------------

    def _evaluate(self, t: float) -> None:
        for r in self.rules:
            active = self.active[r.name]
            if r.kind == BURN_RATE:
                short_b = self._burn(t, r.short_s, r)
                long_b = self._burn(t, r.long_s, r)
                fire = (short_b >= r.threshold
                        and (active or long_b >= r.threshold))
                value = short_b
                why = (f"burn {short_b:.2f}x/{long_b:.2f}x over "
                       f"{r.short_s:g}s/{r.long_s:g}s windows "
                       f"(attainment target "
                       f"{100.0 * r.target_attainment:g}%)")
            elif r.kind == QUEUE_PRESSURE:
                value = self._max_pressure(t)
                if value >= r.threshold:
                    if self._above_since[r.name] is None:
                        self._above_since[r.name] = t
                else:
                    self._above_since[r.name] = None
                since = self._above_since[r.name]
                sustained = 0.0 if since is None else t - since
                fire = since is not None and sustained >= r.short_s - _EPS
                why = (f"max shard pressure {value:.2f} vs "
                       f"{r.threshold:g} (sustained {sustained:g}s / "
                       f"{r.short_s:g}s)")
            else:                  # QUARANTINE_COUNT
                value = float(self._quarantine_count(t, r.window_s))
                fire = value >= r.threshold
                why = (f"{value:g} quarantine decisions in trailing "
                       f"{r.window_s:g}s")
            if fire and not active:
                self._transition(t, ALERT_FIRED, r, value, why)
            elif active and not fire:
                self._transition(t, ALERT_RESOLVED, r, value, why)

    def _transition(self, t: float, kind: str, r: AlertRule,
                    value: float, why: str) -> None:
        self.active[r.name] = kind == ALERT_FIRED
        detail = f"{r.name}: {why}"
        self.history.append(AlertEvent(time=t, kind=kind, rule=r.name,
                                       value=value, detail=detail))
        if self._emit is not None:
            self._emit(EngineEvent(kind=kind, time=t, shard=-1,
                                   detail=detail))

    # -- rule inputs ----------------------------------------------------------

    def _burn(self, t: float, window: float, r: AlertRule) -> float:
        budget = max(1.0 - r.target_attainment, _EPS)
        comps = viols = 0
        for ct, violated in reversed(self._completions):
            if ct <= t - window:
                break              # completions are time-ordered
            if ct > t:
                continue
            comps += 1
            viols += 1 if violated else 0
        return (viols / comps) / budget if comps else 0.0

    def _windows(self) -> List[Tuple[float, float, Dict]]:
        """Captured metric windows as ``(start, end, {series: state})``,
        in capture order."""
        if self._replay_windows is not None:
            return self._replay_windows
        if self._metrics is None:
            return []
        return [(w.start, w.end, w.series) for w in self._metrics.windows]

    def _max_pressure(self, t: float) -> float:
        vis = [w for w in self._windows() if w[1] <= t + _EPS]
        if not vis:
            return 0.0
        # full windows only: the final close() partial is export-side
        # state live evaluation never saw, so replay must skip it too
        width = self._full_width
        if width is None:
            width = max(e - s for s, e, _ in vis)
        vis = [w for w in vis if w[1] - w[0] >= width - _EPS]
        if not vis:
            return 0.0
        _, _, series = max(vis, key=lambda w: w[1])
        best = 0.0
        for sid, state in series.items():
            if sid == "pressure" or sid.startswith("pressure{"):
                best = max(best, float(state.get("value", 0.0)))
        return best

    def _quarantine_count(self, t: float, window: float) -> int:
        if self._replay_audit is not None:
            entries = self._replay_audit
        elif self._audit is not None:
            entries = self._audit.entries
        else:
            entries = ()
        return sum(1 for e in entries
                   if e.action == QUARANTINE and t - window < e.time <= t)

    # -- offline replay -------------------------------------------------------

    def replay(self, timelines, metric_rows: Sequence[Dict] = (),
               audit: Sequence = (), *,
               horizon: Optional[float] = None,
               window: Optional[float] = None) -> List[AlertEvent]:
        """Re-evaluate these rules from exported data and return the
        alert history — identical (time, kind, rule) transitions to the
        live run that produced the export. ``timelines`` /
        ``metric_rows`` / ``audit`` are the three lists
        :func:`repro.obs.export.read_jsonl` returns; the default
        horizon is the last captured metric window end (== the last
        event time the live run saw). ``window`` is the metrics window
        size of the recording run, used to tell the final partial
        window apart from full ones (default: the widest window in the
        export)."""
        from repro.obs.spans import TimelineRecorder

        if isinstance(timelines, TimelineRecorder):
            tls = list(timelines.timelines().values())
        elif isinstance(timelines, dict):
            tls = list(timelines.values())
        else:
            tls = list(timelines)
        sim = AlertRules(self.rules, interval=self.interval)
        sim._completions = sorted(
            (tl.finish, bool(tl.violated)) for tl in tls
            if tl.reject_reason is None and tl.shed_reason is None
            and tl.violated is not None and tl.finish is not None)
        per_window: Dict[Tuple[float, float], Dict] = {}
        for row in metric_rows:
            key = (float(row["window_start"]), float(row["window_end"]))
            per_window.setdefault(key, {})[row["series"]] = row
        sim._replay_windows = [(s, e, series) for (s, e), series
                               in sorted(per_window.items(),
                                         key=lambda kv: kv[0][1])]
        sim._replay_audit = list(audit)
        if window is not None:
            sim._full_width = window
        elif sim._replay_windows:
            sim._full_width = max(e - s for s, e, _ in sim._replay_windows)
        if horizon is None:
            horizon = 0.0
            for _, e, _series in sim._replay_windows:
                horizon = max(horizon, e)
            if not sim._replay_windows:
                for ct, _v in sim._completions:
                    horizon = max(horizon, ct)
                for e in sim._replay_audit:
                    horizon = max(horizon, e.time)
        t = sim.interval
        while t <= horizon:
            sim._evaluate(t)
            t += sim.interval
        return list(sim.history)
