"""Metrics registry: counters, gauges, and log-bucketed histograms.

Every instrument is keyed by ``(name, labels)`` — the same metric name
with different label sets is a different series, Prometheus-style::

    reg = MetricsRegistry(window=60.0)
    reg.counter("jobs_completed", shard=0, tenant="acme").inc()
    reg.gauge("queue_depth", shard=1).set(7)
    reg.histogram("queue_wait_s", shard=0).observe(3.2)

Time is *simulated* time, driven explicitly through :meth:`advance`:
each time the clock crosses a ``window`` boundary the registry captures
a :class:`WindowSnapshot` of every series (cumulative counter values,
last-set gauge values with window min/max, histogram state), which is
what the report layer and the JSONL export consume. Counters therefore
read both cumulatively (``value``) and per-window (adjacent snapshot
deltas, :meth:`MetricsRegistry.window_deltas`).

Histograms are log-bucketed: observation ``v`` lands in bucket
``ceil(log2(v / base))`` (clamped), so a handful of integer bucket
indices cover queue waits from milliseconds to hours with bounded
relative error — the standard trick for latency distributions.

The registry is plain Python state with no background machinery: when
nothing records into it, nothing happens (zero-overhead-when-off lives
one level up — telemetry only subscribes to the event stream when the
user asks for it).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def format_series(name: str, labels: LabelKey) -> str:
    """Canonical ``name{k=v,...}`` series id (sorted labels; bare name
    when there are none)."""
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically increasing count (resets only with the registry)."""

    kind = "counter"

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counters only go up, got inc({amount})")
        self.value += amount

    def read(self) -> Dict[str, float]:
        return {"value": self.value}


class Gauge:
    """Last-set value, with min/max tracked since the last window roll
    so a snapshot shows the excursion, not just the final sample."""

    kind = "gauge"

    def __init__(self) -> None:
        self.value = 0.0
        self._set_ever = False
        self.window_min = math.inf
        self.window_max = -math.inf

    def set(self, value: float) -> None:
        self.value = float(value)
        self._set_ever = True
        self.window_min = min(self.window_min, self.value)
        self.window_max = max(self.window_max, self.value)

    def add(self, delta: float) -> None:
        self.set(self.value + delta)

    def read(self) -> Dict[str, float]:
        lo = self.value if math.isinf(self.window_min) else self.window_min
        hi = self.value if math.isinf(self.window_max) else self.window_max
        return {"value": self.value, "min": lo, "max": hi}

    def roll(self) -> None:
        self.window_min = math.inf
        self.window_max = -math.inf


class Histogram:
    """Log-bucketed distribution: bucket ``i`` holds observations in
    ``(base * 2**(i-1), base * 2**i]`` (bucket 0: ``<= base``). Tracks
    count / sum / min / max exactly; quantiles come from the buckets
    with bounded relative error (a factor of 2 per bucket)."""

    kind = "histogram"

    def __init__(self, base: float = 0.001, max_bucket: int = 64) -> None:
        if base <= 0:
            raise ValueError(f"histogram base must be > 0, got {base}")
        self.base = base
        self.max_bucket = max_bucket
        self.buckets: Dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def bucket_index(self, value: float) -> int:
        if value <= self.base:
            return 0
        i = math.ceil(math.log2(value / self.base))
        return min(i, self.max_bucket)

    def bucket_upper_bound(self, index: int) -> float:
        return self.base * (2.0 ** index)

    def observe(self, value: float) -> None:
        if value < 0:
            raise ValueError(f"histogram observations must be >= 0, "
                             f"got {value}")
        i = self.bucket_index(value)
        self.buckets[i] = self.buckets.get(i, 0) + 1
        self.count += 1
        self.sum += value
        self.min = min(self.min, value)
        self.max = max(self.max, value)

    def quantile(self, q: float) -> float:
        """Upper bound of the bucket holding the q-quantile (0 when
        empty)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile wants q in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        for i in sorted(self.buckets):
            seen += self.buckets[i]
            if seen >= rank:
                return min(self.bucket_upper_bound(i), self.max)
        return self.max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def read(self) -> Dict[str, object]:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "mean": self.mean,
            # base rides along so exported rows can reconstruct bucket
            # upper bounds (base * 2**index) for offline quantiles
            "base": self.base,
            "buckets": dict(sorted(self.buckets.items())),
        }


@dataclass
class WindowSnapshot:
    """All series' states captured at one window boundary. Counter and
    histogram values are cumulative-as-of-``end``; gauge min/max cover
    just this window."""

    start: float
    end: float
    series: Dict[str, Dict[str, object]] = field(default_factory=dict)


class MetricsRegistry:
    """The `(name, labels)`-keyed instrument store plus the sim-time
    window clock."""

    def __init__(self, window: float = 60.0) -> None:
        if window <= 0:
            raise ValueError(f"window must be > 0 seconds, got {window}")
        self.window = window
        self._instruments: Dict[Tuple[str, LabelKey], object] = {}
        self._kinds: Dict[str, str] = {}       # name -> kind (consistency)
        self.windows: List[WindowSnapshot] = []
        self._window_start = 0.0
        self.now = 0.0

    # -- instrument accessors ------------------------------------------------

    def _get(self, cls, name: str, labels: Dict[str, object], **kwargs):
        want = cls.kind
        have = self._kinds.setdefault(name, want)
        if have != want:
            raise TypeError(f"metric {name!r} already registered as "
                            f"{have}, requested {want}")
        key = (name, _label_key(labels))
        inst = self._instruments.get(key)
        if inst is None:
            inst = cls(**kwargs)
            self._instruments[key] = inst
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, base: float = 0.001, **labels) -> Histogram:
        return self._get(Histogram, name, labels, base=base)

    def series(self) -> List[str]:
        """Every registered series id, sorted."""
        return sorted(format_series(n, lk) for n, lk in self._instruments)

    # -- window clock --------------------------------------------------------

    def advance(self, t: float) -> None:
        """Move the sim clock to ``t``, capturing a snapshot for every
        completed window boundary crossed on the way. Safe to call with
        a non-advancing ``t`` (no-op)."""
        while t >= self._window_start + self.window:
            end = self._window_start + self.window
            self._capture(self._window_start, end)
            self._window_start = end
        self.now = max(self.now, t)

    def close(self) -> None:
        """Capture the final partial window (idempotent for an empty
        remainder)."""
        if self.now > self._window_start:
            self._capture(self._window_start, self.now)
            self._window_start = self.now

    def _capture(self, start: float, end: float) -> None:
        snap = WindowSnapshot(start=start, end=end)
        for (name, lk), inst in sorted(self._instruments.items()):
            snap.series[format_series(name, lk)] = inst.read()
        self.windows.append(snap)
        for inst in self._instruments.values():
            if isinstance(inst, Gauge):
                inst.roll()

    # -- reads ---------------------------------------------------------------

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """Current state of every series (not window-aligned)."""
        return {format_series(n, lk): inst.read()
                for (n, lk), inst in sorted(self._instruments.items())}

    def value(self, name: str, **labels) -> float:
        """Convenience scalar read: counter/gauge value (0 when the
        series does not exist)."""
        inst = self._instruments.get((name, _label_key(labels)))
        if inst is None:
            return 0.0
        return inst.read().get("value", 0.0)   # type: ignore[union-attr]

    def total(self, name: str) -> float:
        """Sum of a counter/gauge ``value`` across all label sets."""
        out = 0.0
        for (n, _lk), inst in self._instruments.items():
            if n == name:
                out += inst.read().get("value", 0.0)  # type: ignore
        return out

    def window_deltas(self, name: str, **labels) -> List[Tuple[float, float,
                                                               float]]:
        """Per-window increments of a cumulative (counter) series:
        ``[(start, end, delta), ...]`` over the captured windows."""
        sid = format_series(name, _label_key(labels))
        out: List[Tuple[float, float, float]] = []
        prev = 0.0
        for w in self.windows:
            cur = float(w.series.get(sid, {}).get("value", prev))
            out.append((w.start, w.end, cur - prev))
            prev = cur
        return out

    # -- export --------------------------------------------------------------

    def to_dicts(self) -> Iterable[Dict[str, object]]:
        """One JSON-able dict per (window, series) — the metrics JSONL
        rows."""
        for w in self.windows:
            for sid, state in w.series.items():
                yield {"type": "metric", "window_start": w.start,
                       "window_end": w.end, "series": sid, **state}
