"""Exporters: Chrome-trace/Perfetto JSON and structured JSONL.

Chrome trace format (the JSON Array/Object flavor both
``chrome://tracing`` and https://ui.perfetto.dev open directly):

* one **process per shard** (``pid`` = shard index, named via ``M``
  metadata events), plus a ``pid = shards`` control-plane process for
  elastic steal/resize/reject instants;
* one **thread per job** (``tid`` = job id) holding that job's
  complete-duration spans (``ph: "X"``) — queued / init / running, with
  SLO class, tenant, GPUs and the violation verdict in ``args``;
* **counter tracks** (``ph: "C"``) per shard for queue depth, pressure,
  and running GPUs, sampled from the metrics windows;
* timestamps are microseconds of simulated time (Chrome's native unit).

The JSONL export is line-per-record structured data for offline
analysis: ``{"type": "timeline" | "metric" | "audit", ...}`` — round-
trippable back into :class:`~repro.obs.spans.JobTimeline` /
:class:`~repro.obs.audit.AuditEntry` objects via :func:`read_jsonl`.

:func:`validate_chrome_trace` is the schema check CI runs against
exported artifacts: well-formed JSON, required keys per event, and
monotone non-decreasing ``ts`` per ``(pid, tid)`` lane.

Run as a module to validate a file::

    PYTHONPATH=src python -m repro.obs.export --validate run.trace.json
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.obs.audit import AuditEntry, AuditLog
from repro.obs.metrics import MetricsRegistry
from repro.obs.spans import JobTimeline, TimelineRecorder

_US = 1e6                     # sim seconds -> Chrome-trace microseconds

# Stable per-phase colors (Chrome trace color names).
_PHASE_COLOR = {"queued": "thread_state_runnable",
                "init": "thread_state_iowait",
                "running": "thread_state_running",
                "rejected": "terrible"}


def _timelines_list(timelines) -> List[JobTimeline]:
    if isinstance(timelines, TimelineRecorder):
        return [tl for _, tl in sorted(timelines.timelines().items())]
    if isinstance(timelines, dict):
        return [tl for _, tl in sorted(timelines.items())]
    return list(timelines)


def to_chrome_trace(
    timelines,
    metrics: Optional[MetricsRegistry] = None,
    audit: Optional[AuditLog] = None,
    *,
    shards: Optional[int] = None,
) -> Dict:
    """Build the Chrome-trace document (JSON Object Format: a dict with
    ``traceEvents``) from recorded telemetry. Any of the three sources
    may be omitted."""
    tls = _timelines_list(timelines)
    events: List[Dict] = []
    seen_pids = set()

    # Horizon for rendering still-open spans (jobs that never reached
    # JOB_DONE): latest timestamp anywhere in the recording. They are
    # drawn as truncated spans up to the horizon instead of dropped.
    horizon = 0.0
    for tl in tls:
        for s in tl.spans:
            horizon = max(horizon, s.start,
                          s.end if s.end is not None else s.start)
        for h in tl.hops:
            horizon = max(horizon, h.time)

    for tl in tls:
        for s in tl.spans:
            end = s.end if s.end is not None else max(horizon, s.start)
            truncated = s.truncated or s.end is None
            seen_pids.add(s.shard)
            events.append({
                "name": s.phase,
                "cat": "job",
                "ph": "X",
                "ts": s.start * _US,
                "dur": (end - s.start) * _US,
                "pid": s.shard,
                "tid": tl.job_id,
                "cname": ("terrible" if truncated
                          else _PHASE_COLOR.get(s.phase)),
                "args": {
                    "task_id": tl.task_id, "llm": tl.llm,
                    "tenant": tl.tenant, "slo_class": tl.slo_class,
                    "gpus": tl.gpus, "used_bank": tl.used_bank,
                    "deadline_s": tl.deadline, "violated": tl.violated,
                    "truncated": truncated, "retries": tl.retries,
                    "shed_reason": tl.shed_reason,
                },
            })
        for h in tl.hops:
            seen_pids.add(h.dst)
            events.append({
                "name": f"steal job {tl.job_id}",
                "cat": "elastic", "ph": "i", "s": "p",
                "ts": h.time * _US, "pid": h.dst, "tid": tl.job_id,
                "args": {"src": h.src, "dst": h.dst},
            })

    if metrics is not None:
        events.extend(_counter_events(metrics, seen_pids))

    n_shards = (shards if shards is not None
                else (max(seen_pids) + 1 if seen_pids else 0))
    ctl_pid = max(n_shards, max(seen_pids) + 1 if seen_pids else 0)
    if audit is not None:
        for e in audit.entries:
            ev = {
                "name": e.action,
                "cat": "elastic", "ph": "i", "s": "g",
                "ts": e.time * _US, "pid": ctl_pid, "tid": 0,
                "args": {"shard": e.shard, "job_id": e.job_id,
                         "tenant": e.tenant, "detail": e.detail,
                         "inputs": e.inputs},
            }
            # alert windows stand out: red firing, green resolution
            if e.action == "alert_fired":
                ev["cname"] = "bad"
            elif e.action == "alert_resolved":
                ev["cname"] = "good"
            events.append(ev)

    meta: List[Dict] = []
    for pid in sorted(seen_pids):
        meta.append({"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                     "args": {"name": f"shard {pid}"}})
        meta.append({"name": "process_sort_index", "ph": "M", "pid": pid,
                     "tid": 0, "args": {"sort_index": pid}})
    if audit is not None and audit.entries:
        meta.append({"name": "process_name", "ph": "M", "pid": ctl_pid,
                     "tid": 0, "args": {"name": "elastic control plane"}})
        meta.append({"name": "process_sort_index", "ph": "M", "pid": ctl_pid,
                     "tid": 0, "args": {"sort_index": ctl_pid}})
    for tl in tls:
        for pid in {s.shard for s in tl.spans}:
            meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                         "tid": tl.job_id,
                         "args": {"name": f"job {tl.job_id} "
                                          f"({tl.tenant}/{tl.llm})"}})

    # Sort payload events by ts (metadata first): Perfetto tolerates any
    # order, but monotone lanes make the file diffable and validatable.
    events.sort(key=lambda e: (e["ts"], e["pid"], e["tid"]))
    return {
        "traceEvents": meta + events,
        "displayTimeUnit": "ms",
        "otherData": {"source": "repro.obs", "clock": "simulated-time"},
    }


def _counter_events(metrics: MetricsRegistry, seen_pids) -> List[Dict]:
    """Per-shard counter tracks sampled at each metrics window end."""
    out: List[Dict] = []
    for w in metrics.windows:
        for sid, state in w.series.items():
            if "{" not in sid or "shard=" not in sid:
                continue
            name = sid[: sid.index("{")]
            if name not in ("queue_depth", "pressure", "running_gpus"):
                continue
            labels = dict(kv.split("=", 1) for kv in
                          sid[sid.index("{") + 1:-1].split(","))
            try:
                pid = int(labels["shard"])
            except (KeyError, ValueError):
                continue
            seen_pids.add(pid)
            out.append({
                "name": name, "cat": "metrics", "ph": "C",
                "ts": w.end * _US, "pid": pid, "tid": 0,
                "args": {name: state.get("value", 0.0)},
            })
    return out


def write_chrome_trace(path: str, timelines,
                       metrics: Optional[MetricsRegistry] = None,
                       audit: Optional[AuditLog] = None,
                       *, shards: Optional[int] = None) -> str:
    doc = to_chrome_trace(timelines, metrics, audit, shards=shards)
    with open(path, "w") as f:
        json.dump(doc, f, default=float)
    return path


# -- JSONL -------------------------------------------------------------------


def jsonl_records(timelines=None,
                  metrics: Optional[MetricsRegistry] = None,
                  audit: Optional[AuditLog] = None) -> Iterable[Dict]:
    if timelines is not None:
        for tl in _timelines_list(timelines):
            yield tl.to_dict()
    if metrics is not None:
        yield from metrics.to_dicts()
    if audit is not None:
        yield from audit.to_dicts()


def write_jsonl(path: str, timelines=None,
                metrics: Optional[MetricsRegistry] = None,
                audit: Optional[AuditLog] = None) -> str:
    with open(path, "w") as f:
        for rec in jsonl_records(timelines, metrics, audit):
            f.write(json.dumps(rec, default=float) + "\n")
    return path


def read_jsonl(path: str) -> Dict[str, List]:
    """Load a JSONL export back into typed objects:
    ``{"timelines": [JobTimeline], "metrics": [dict], "audit":
    [AuditEntry]}``."""
    out: Dict[str, List] = {"timelines": [], "metrics": [], "audit": []}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            kind = rec.get("type")
            if kind == "timeline":
                out["timelines"].append(JobTimeline.from_dict(rec))
            elif kind == "metric":
                out["metrics"].append(rec)
            elif kind == "audit":
                out["audit"].append(AuditEntry.from_dict(rec))
    return out


# -- validation --------------------------------------------------------------


def validate_chrome_trace(doc) -> List[str]:
    """Schema-check a Chrome-trace document. Returns a list of problems
    (empty = valid): top-level shape, per-event required keys, and
    non-decreasing ``ts`` within each (pid, tid) lane for duration
    events."""
    problems: List[str] = []
    if isinstance(doc, list):
        events: Sequence[Dict] = doc      # JSON Array Format
    elif isinstance(doc, dict):
        events = doc.get("traceEvents", None)
        if events is None:
            return ["missing top-level 'traceEvents'"]
    else:
        return [f"trace must be a JSON object or array, got {type(doc)}"]

    last_ts: Dict[Tuple[int, int], float] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph is None:
            problems.append(f"event {i}: missing 'ph'")
            continue
        if ph == "M":
            continue                      # metadata: no ts required
        for key in ("ts", "pid", "tid", "name"):
            if key not in ev:
                problems.append(f"event {i} ({ph}): missing {key!r}")
        if "ts" not in ev:
            continue
        ts = ev["ts"]
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i}: bad ts {ts!r}")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: X event needs dur >= 0, "
                                f"got {dur!r}")
            lane = (ev.get("pid"), ev.get("tid"))
            if ts + 1e-6 < last_ts.get(lane, float("-inf")):
                problems.append(
                    f"event {i}: ts goes backwards in lane pid={lane[0]} "
                    f"tid={lane[1]} ({ts} < {last_ts[lane]})")
            last_ts[lane] = max(last_ts.get(lane, float("-inf")), ts)
    return problems


def validate_chrome_trace_file(path: str) -> List[str]:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"cannot load {path}: {e}"]
    return validate_chrome_trace(doc)


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="validate a Chrome-trace export / run SLO forensics "
                    "on a JSONL export")
    ap.add_argument("--validate", metavar="TRACE_JSON",
                    help="schema-check a Chrome-trace JSON file")
    ap.add_argument("--forensics", metavar="TRACE_JSONL",
                    help="per-violation blame attribution from a JSONL "
                         "export (timelines + audit)")
    ap.add_argument("--forensics-out", metavar="OUT_JSON",
                    help="also write the full forensics report (per-job "
                         "breakdowns included) as JSON")
    args = ap.parse_args(argv)
    if not args.validate and not args.forensics:
        ap.error("nothing to do: pass --validate and/or --forensics")
    rc = 0
    if args.validate:
        problems = validate_chrome_trace_file(args.validate)
        if problems:
            print(f"{args.validate}: INVALID ({len(problems)} problems)")
            for p in problems[:20]:
                print(f"  - {p}")
            rc = 1
        else:
            print(f"{args.validate}: OK (well-formed Chrome trace)")
    if args.forensics:
        from repro.obs.forensics import analyze

        try:
            loaded = read_jsonl(args.forensics)
        except (OSError, json.JSONDecodeError, KeyError, ValueError) as e:
            print(f"{args.forensics}: cannot load JSONL export: {e}")
            return 1
        report = analyze(loaded["timelines"], loaded["audit"])
        print(report.render())
        if args.forensics_out:
            with open(args.forensics_out, "w") as f:
                json.dump(report.to_dict(), f, indent=2, default=float)
            print(f"wrote {args.forensics_out}")
    return rc


if __name__ == "__main__":
    import sys

    sys.exit(main())
