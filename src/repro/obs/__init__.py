"""Fleet telemetry plane: metrics, span timelines, traces, audit.

:class:`Telemetry` is the one-call wiring for the whole observability
subsystem::

    tel = Telemetry(window=30.0)
    fab = ClusterFabric(cfg, "prompttuner", shards=8, elastic=ElasticConfig())
    tel.attach(fab)
    fab.run(jobs)
    print(tel.report())                       # SLO-attainment time series
    tel.export_chrome_trace("run.trace.json") # open in ui.perfetto.dev
    tel.export_jsonl("run.jsonl")             # offline analysis

It subscribes one callback to the fabric's existing typed event stream
(``on_event``) and passively derives everything from it:

* **metrics** (:class:`~repro.obs.metrics.MetricsRegistry`) — engine
  rounds / queue depth / warm-vs-cold starts, per-shard/per-tenant
  throughput and placement outcomes, elastic steals / resizes /
  rejections, and :class:`~repro.cluster.health.ShardHealth` pressure
  and slack sampled as gauges each scheduler round;
* **span timelines** (:class:`~repro.obs.spans.TimelineRecorder`) —
  per-job submitted → queued → init → running → done lifecycles with
  shard hops, exportable as Chrome-trace/Perfetto JSON;
* **audit log** (:class:`~repro.obs.audit.AuditLog`) — attached to the
  fabric's :class:`~repro.cluster.elastic.ElasticController` so every
  steal / resize / rejection / reclaim records the ShardHealth inputs
  it acted on.

Recording is strictly opt-in: nothing subscribes until
:meth:`Telemetry.attach`, so an un-instrumented run takes the engine's
``if not self._subscribers: return`` fast path and produces
float-for-float identical results (pinned by ``tests/test_obs.py``).
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.cluster.elastic import (
    ALERT_FIRED,
    ALERT_RESOLVED,
    JOB_REJECTED,
    JOB_STOLEN,
    SHARD_RESIZED,
)
from repro.cluster.engine import ARRIVAL, JOB_DONE, ROUND, EngineEvent
from repro.cluster.faults import (
    JOB_ORPHANED,
    JOB_RETRIED,
    JOB_SHED,
    SHARD_FAILED,
    SHARD_RECOVERED,
    SHARD_SLOWED,
    SHARD_WARNED,
)
from repro.cluster.health import shard_health

from repro.obs.alerts import (
    DEFAULT_RULES,
    AlertEvent,
    AlertRule,
    AlertRules,
)
from repro.obs.audit import AuditEntry, AuditLog, health_dict
from repro.obs.export import (
    read_jsonl,
    to_chrome_trace,
    validate_chrome_trace,
    validate_chrome_trace_file,
    write_chrome_trace,
    write_jsonl,
)
from repro.obs.forensics import (
    CAUSES,
    ForensicsReport,
    JobBlame,
    analyze,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    WindowSnapshot,
)
from repro.obs.report import render_report, report_rows
from repro.obs.spans import JobTimeline, ShardHop, Span, TimelineRecorder

__all__ = [
    "ALERT_FIRED",
    "ALERT_RESOLVED",
    "CAUSES",
    "DEFAULT_RULES",
    "AlertEvent",
    "AlertRule",
    "AlertRules",
    "AuditEntry",
    "AuditLog",
    "Counter",
    "ForensicsReport",
    "Gauge",
    "Histogram",
    "JobBlame",
    "JobTimeline",
    "MetricsRegistry",
    "ShardHop",
    "Span",
    "Telemetry",
    "TimelineRecorder",
    "WindowSnapshot",
    "analyze",
    "health_dict",
    "read_jsonl",
    "render_report",
    "report_rows",
    "to_chrome_trace",
    "validate_chrome_trace",
    "validate_chrome_trace_file",
    "write_chrome_trace",
    "write_jsonl",
]


class Telemetry:
    """Wires a metrics registry, a timeline recorder, and an audit log
    into one fabric (or bare engine wrapped in a 1-shard fabric).

    ``window`` is the metrics snapshot period in *simulated* seconds.
    """

    def __init__(self, *, window: float = 60.0,
                 alerts: Optional[AlertRules] = None) -> None:
        self.metrics = MetricsRegistry(window=window)
        self.timeline = TimelineRecorder()
        self.audit = AuditLog()
        self.alerts = alerts
        self._fabric = None

    # -- wiring --------------------------------------------------------------

    def attach(self, fabric) -> "Telemetry":
        """Subscribe to ``fabric``'s event stream and hook the audit log
        into its elastic controller (when present). Attach exactly once,
        any time before ``run``; returns self for chaining."""
        if self._fabric is not None:
            raise ValueError("Telemetry is already attached to a fabric; "
                             "use one Telemetry per fabric")
        self._fabric = fabric
        fabric.on_event(self._on_event)
        controller = getattr(fabric, "controller", None)
        if controller is not None:
            controller.audit = self.audit
        faults = getattr(fabric, "faults", None)
        if faults is not None:
            faults.audit = self.audit
        if self.alerts is not None:
            # subscribed AFTER telemetry: metric windows are captured
            # before any rule reads them — the same visibility the
            # offline replay reconstructs. Emissions go through
            # fabric.announce so the controller sees them too.
            self.alerts.bind(emit=fabric.announce, metrics=self.metrics,
                             audit=self.audit)
            fabric.on_event(self.alerts.on_event)
        return self

    @property
    def attached(self) -> bool:
        return self._fabric is not None

    # -- event folding -------------------------------------------------------

    def _on_event(self, ev: EngineEvent) -> None:
        self.metrics.advance(ev.time)
        first_arrival = (ev.kind == ARRIVAL and ev.job is not None
                         and self.timeline.timeline(ev.job.job_id) is None)
        self.timeline.on_event(ev)
        kind = ev.kind
        if kind == ROUND:
            self.metrics.counter("rounds", shard=ev.shard).inc()
            self._sample_shard(ev.shard)
        elif kind == ARRIVAL:
            # a steal re-admission re-emits ARRIVAL on the receiver;
            # only the first arrival is a submission
            if first_arrival:
                self.metrics.counter("jobs_submitted", shard=ev.shard,
                                     tenant=ev.job.tenant).inc()
                self.metrics.counter("placements", shard=ev.shard).inc()
        elif kind == JOB_DONE:
            self._on_job_done(ev)
        elif kind == JOB_STOLEN:
            self.metrics.counter("steals", shard=ev.shard).inc()
        elif kind == SHARD_RESIZED:
            self.metrics.counter("resizes", shard=ev.shard).inc()
        elif kind == JOB_REJECTED:
            self.metrics.counter("rejections",
                                 tenant=ev.job.tenant).inc()
        elif kind == SHARD_FAILED:
            self.metrics.counter("shard_failures", shard=ev.shard).inc()
        elif kind == SHARD_RECOVERED:
            self.metrics.counter("shard_recoveries", shard=ev.shard).inc()
        elif kind == SHARD_WARNED:
            self.metrics.counter("shard_warnings", shard=ev.shard).inc()
        elif kind == SHARD_SLOWED:
            self.metrics.counter("shard_slowdowns", shard=ev.shard).inc()
        elif kind == JOB_ORPHANED:
            self.metrics.counter("jobs_orphaned", shard=ev.shard,
                                 tenant=ev.job.tenant).inc()
        elif kind == JOB_RETRIED:
            self.metrics.counter("jobs_retried", shard=ev.shard,
                                 tenant=ev.job.tenant).inc()
        elif kind == JOB_SHED:
            self.metrics.counter("jobs_shed", tenant=ev.job.tenant).inc()
        elif kind == ALERT_FIRED or kind == ALERT_RESOLVED:
            # alert transitions land in the audit log so they export as
            # JSONL records and Chrome-trace instants with no extra
            # wiring (the rule name leads the detail string)
            self.metrics.counter(
                "alerts_fired" if kind == ALERT_FIRED
                else "alerts_resolved").inc()
            self.audit.decision(time=ev.time, action=kind, shard=ev.shard,
                                detail=ev.detail or "")

    def _sample_shard(self, shard: int) -> None:
        """ShardHealth pressure/slack signals as gauges, sampled each
        scheduler round."""
        if self._fabric is None or not (0 <= shard
                                        < len(self._fabric.shards)):
            return
        faults = getattr(self._fabric, "faults", None)
        h = shard_health(self._fabric.shards[shard], shard, faults)
        m = self.metrics
        m.gauge("queue_depth", shard=shard).set(h.pending_jobs)
        m.gauge("pressure", shard=shard).set(h.pressure)
        m.gauge("running_gpus", shard=shard).set(h.running_gpus)
        m.gauge("cold_free", shard=shard).set(h.cold_free)
        m.gauge("warm_idle", shard=shard).set(h.warm_idle)
        if h.min_slack != float("inf"):
            m.gauge("min_slack_s", shard=shard).set(h.min_slack)
        if faults is not None:
            m.gauge("alive", shard=shard).set(1.0 if h.alive else 0.0)
            m.gauge("draining", shard=shard).set(1.0 if h.draining else 0.0)
            m.gauge("recent_failures", shard=shard).set(h.recent_failures)

    def _on_job_done(self, ev: EngineEvent) -> None:
        job = ev.job
        m = self.metrics
        m.counter("jobs_completed", shard=ev.shard, tenant=job.tenant).inc()
        if ev.time > job.deadline + 1e-9:
            m.counter("slo_violations", shard=ev.shard,
                      tenant=job.tenant).inc()
        start = job.start_time if job.start_time is not None else ev.time
        m.histogram("queue_wait_s", shard=ev.shard).observe(
            max(start - job.submit_time, 0.0))
        m.histogram("exec_s", shard=ev.shard).observe(
            max(ev.time - start, 0.0))
        prof = job.profile()
        alloc = job.init_overhead - (prof.bank_lookup_s if job.used_bank
                                     else 0.0)
        # warm-vs-cold classification: policies pay ~warm_overhead on a
        # warm hit and >= ~cold_overhead (INFless jitters it 0.8-2.2x)
        # on a cold start; split at 75% of the profile's cold overhead.
        start_kind = "cold" if alloc >= 0.75 * prof.cold_overhead else "warm"
        m.counter("starts", kind=start_kind, shard=ev.shard).inc()
        if job.used_bank:
            m.counter("bank_routed", shard=ev.shard).inc()

    # -- reads / exports -----------------------------------------------------

    def report(self, *, bucket: Optional[float] = None,
               title: str = "SLO attainment over time") -> str:
        """The per-time-bucket SLO-attainment / queue-depth report."""
        self.metrics.close()
        return render_report(self.timeline, self.metrics.to_dicts(),
                             bucket=bucket or self.metrics.window,
                             title=title)

    def export_chrome_trace(self, path: str) -> str:
        """Write the Chrome-trace/Perfetto JSON for this run."""
        self.metrics.close()
        shards = len(self._fabric.shards) if self._fabric is not None else None
        return write_chrome_trace(path, self.timeline, self.metrics,
                                  self.audit, shards=shards)

    def export_jsonl(self, path: str) -> str:
        """Write the structured JSONL export (timelines + metric windows
        + audit entries)."""
        self.metrics.close()
        return write_jsonl(path, self.timeline, self.metrics, self.audit)

    def forensics(self) -> ForensicsReport:
        """Per-violation blame attribution rolled up fleet-wide (see
        :mod:`repro.obs.forensics`)."""
        return analyze(self.timeline, self.audit)

    def summary_counters(self) -> Dict[str, float]:
        """Cross-label totals of the headline counters (quick asserts
        and logs)."""
        return {name: self.metrics.total(name)
                for name in ("jobs_submitted", "jobs_completed",
                             "slo_violations", "steals", "resizes",
                             "rejections", "rounds", "shard_failures",
                             "shard_recoveries", "jobs_orphaned",
                             "jobs_retried", "jobs_shed",
                             "alerts_fired", "alerts_resolved")}
