"""CLI entry: ``python -m repro.obs --validate run.trace.json``.

Delegates to :func:`repro.obs.export.main` (also reachable as
``python -m repro.obs.export``, modulo a harmless runpy warning).
"""
import sys

from repro.obs.export import main

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
