"""SLO forensics: deterministic per-violation blame attribution.

The telemetry plane records *that* a job violated its SLO; this module
answers *why*, in seconds. For every violated or shed job,
:func:`analyze` walks the job's :class:`~repro.obs.spans.JobTimeline`
spans, its :class:`~repro.obs.spans.ShardHop` moves, and the
:class:`~repro.obs.audit.AuditLog` (fault-plane slowdown factors and
the elastic decisions that placed/moved the job) and decomposes the
observed lifecycle ``[submit, end]`` into cause categories:

* ``queue_wait``   — time queued with no elastic move to show for it;
* ``cold_start``   — the final attempt's init span (allocation +
  instance warm-up + bank lookup + checkpoint-restore tax);
* ``crash_rework`` — truncated init/running spans: work a shard
  failure threw away;
* ``retry_backoff``— gaps between an orphaning and the retry re-entry
  (the recovery policy's exponential backoff);
* ``steal_hop``    — queued time on a shard the job was stolen *to*
  (the move's landing cost);
* ``slowdown``     — the straggler tax on the final attempt: wall time
  in excess of what the shard would have taken at speed x1, rebuilt
  from the audited ``shard_slowed`` factors (a ``shard_failed`` entry
  resets the factor — the engine's crash path does);
* ``placement``    — queued time on a shard the controller later stole
  the job *off*: evidence the original placement was wrong, with the
  specific audit decision it indicts attached;
* ``exec``         — nominal execution (the final attempt's running
  span minus the slowdown tax). Not a violation cause per se, but it
  can retain blame when execution alone exceeds the SLO.

**Reconciliation invariant** (pinned by tests): the category seconds
tile the observed lifecycle exactly, and the *blame* — what is left of
each category after the job's slack allowance is consumed in
:data:`_CONSUME_ORDER` — sums to the job's measured overrun:

* completed-late job: ``sum(blame) == finish - deadline``;
* shed job (no finite finish): the whole observed lifecycle is blamed,
  ``sum(blame) == end - start`` — none of a shed job's spent time fit
  inside a budget it never met.

The lifecycle anchor ``start`` is ``min(submit_time, first span
start)``: a shard crash can orphan-and-retry a job *before* its
nominal arrival (the whole trace is pre-submitted to shard queues), so
observed activity may legitimately precede ``submit_time``.

Everything is computed from exported data — a reloaded JSONL trace
(:func:`repro.obs.export.read_jsonl`) produces the byte-identical
report the live recorder does.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.cluster.elastic import DRAIN, JOB_STOLEN
from repro.cluster.faults import SHARD_FAILED, SHARD_SLOWED
from repro.obs.audit import AuditLog
from repro.obs.spans import INIT, QUEUED, REJECTED, RUNNING, JobTimeline

# The seven violation causes, in report order. EXEC is the residual
# nominal-execution category; it only shows up in a blame breakdown
# when the job could not have met its SLO even with a perfect fleet.
CAUSES = ("queue_wait", "cold_start", "crash_rework", "retry_backoff",
          "steal_hop", "slowdown", "placement")
EXEC = "exec"

# Order in which a job's slack allowance (the part of its lifecycle
# that fit inside the deadline) is consumed. Benign categories come
# first, so the blame lands on the pathological tail: a job that spent
# its whole budget executing and then waited out a retry backoff
# blames the backoff, not the execution.
_CONSUME_ORDER = (EXEC, "cold_start", "queue_wait", "placement",
                  "steal_hop", "retry_backoff", "crash_rework", "slowdown")

_EPS = 1e-9


def _timelines_list(timelines) -> List[JobTimeline]:
    from repro.obs.spans import TimelineRecorder

    if isinstance(timelines, TimelineRecorder):
        return [tl for _, tl in sorted(timelines.timelines().items())]
    if isinstance(timelines, dict):
        return [tl for _, tl in sorted(timelines.items())]
    return sorted(timelines, key=lambda tl: tl.job_id)


def _audit_entries(audit) -> List:
    if audit is None:
        return []
    if isinstance(audit, AuditLog):
        return list(audit.entries)
    return list(audit)


def _slow_windows(entries) -> Dict[int, List[Tuple[float, float]]]:
    """Per-shard sorted ``(time, speed_factor)`` steps rebuilt from the
    audit log: ``shard_slowed`` entries carry the factor in their
    inputs; a ``shard_failed`` entry resets to x1 (the engine's crash
    path clears the multiplier)."""
    out: Dict[int, List[Tuple[float, float]]] = {}
    for e in entries:
        if e.action == SHARD_SLOWED:
            inputs = e.inputs if isinstance(e.inputs, dict) else {}
            try:
                factor = float(inputs.get("factor", 1.0))
            except (TypeError, ValueError):
                factor = 1.0
            out.setdefault(e.shard, []).append((e.time, factor))
        elif e.action == SHARD_FAILED:
            out.setdefault(e.shard, []).append((e.time, 1.0))
    for steps in out.values():
        steps.sort()
    return out


def _speed_at(slow: Dict[int, List[Tuple[float, float]]], shard: int,
              t: float) -> float:
    factor = 1.0
    for ts, f in slow.get(shard, ()):
        if ts <= t + _EPS:
            factor = f
        else:
            break
    return factor


@dataclass
class JobBlame:
    """One violated/shed job's decomposition and blame breakdown."""

    job_id: int
    tenant: str
    slo_class: str
    shard: int
    submit_time: float
    start: float                    # observed lifecycle anchor:
                                    # min(submit, first span start)
    deadline: float
    end: float                      # finish, or the shed/truncation instant
    overrun_s: float                # what the blame must sum to
    shed: bool
    retries: int
    hops: int
    seconds: Dict[str, float]       # full lifecycle decomposition
    blame: Dict[str, float]         # past-allowance remainder per cause
    primary_cause: str
    indicts: Optional[Dict] = None  # audit decision `placement` points at

    def to_dict(self) -> Dict:
        return {
            "job_id": self.job_id, "tenant": self.tenant,
            "slo_class": self.slo_class, "shard": self.shard,
            "submit_time": self.submit_time, "start": self.start,
            "deadline": self.deadline,
            "end": self.end, "overrun_s": self.overrun_s,
            "shed": self.shed, "retries": self.retries, "hops": self.hops,
            "seconds": dict(self.seconds), "blame": dict(self.blame),
            "primary_cause": self.primary_cause, "indicts": self.indicts,
        }


@dataclass
class ForensicsReport:
    """Fleet-wide rollup: blamed seconds per cause across every
    violated/shed job, plus the per-job breakdowns."""

    jobs: List[JobBlame] = field(default_factory=list)
    totals: Dict[str, float] = field(default_factory=dict)
    primary_counts: Dict[str, int] = field(default_factory=dict)
    violated: int = 0
    completed_late: int = 0
    shed: int = 0

    def cause_shares(self) -> Dict[str, float]:
        """Each cause's fraction of all blamed seconds (zeros when
        nothing violated)."""
        total = sum(self.totals.values())
        if total <= 0:
            return {c: 0.0 for c in self.totals}
        return {c: v / total for c, v in self.totals.items()}

    def job(self, job_id: int) -> Optional[JobBlame]:
        for jb in self.jobs:
            if jb.job_id == job_id:
                return jb
        return None

    def to_dict(self) -> Dict:
        return {
            "type": "forensics",
            "violated": self.violated,
            "completed_late": self.completed_late,
            "shed": self.shed,
            "totals": dict(self.totals),
            "shares": self.cause_shares(),
            "primary_counts": dict(self.primary_counts),
            "jobs": [jb.to_dict() for jb in self.jobs],
        }

    def render(self, *, title: str = "top causes of violation") -> str:
        shares = self.cause_shares()
        lines = [f"== SLO forensics: {title} ==",
                 f"{'cause':<14s} {'blamed_s':>10s} {'share%':>7s} "
                 f"{'primary':>8s}"]
        order = sorted(self.totals,
                       key=lambda c: (-self.totals[c],
                                      (CAUSES + (EXEC,)).index(c)))
        for c in order:
            lines.append(f"{c:<14s} {self.totals[c]:>10.1f} "
                         f"{100.0 * shares.get(c, 0.0):>7.1f} "
                         f"{self.primary_counts.get(c, 0):>8d}")
        lines.append(f"total: {self.violated} violated jobs "
                     f"({self.completed_late} completed late, "
                     f"{self.shed} shed), "
                     f"{sum(self.totals.values()):.1f} blamed seconds")
        return "\n".join(lines)


def _decompose(tl: JobTimeline,
               slow: Dict[int, List[Tuple[float, float]]]
               ) -> Tuple[Dict[str, float], float, float]:
    """Tile the observed lifecycle ``[start, end]`` into category
    seconds, chronologically."""
    spans = [s for s in tl.spans
             if s.end is not None and s.phase != REJECTED]
    end = spans[-1].end
    # a crash can orphan-and-retry a pre-submitted job before its
    # nominal arrival, so the anchor is the earlier of the two
    t0 = min(tl.submit_time, spans[0].start)
    sec: Dict[str, float] = {c: 0.0 for c in CAUSES}
    sec[EXEC] = 0.0
    steal_hops = [h for h in tl.hops if h.kind == "steal"]
    final_run = None
    for i, s in enumerate(spans):
        if s.phase == RUNNING and not s.truncated:
            final_run = i
    cursor = t0
    for i, s in enumerate(spans):
        gap = s.start - cursor
        if gap > _EPS:
            # a gap between spans is dead air: before the first span it
            # is pre-placement queueing; after a truncated span it is
            # the recovery policy's retry backoff
            sec["queue_wait" if i == 0 else "retry_backoff"] += gap
        dur = s.end - s.start
        if s.phase == QUEUED:
            if any(abs(h.time - s.end) <= _EPS and h.src == s.shard
                   for h in steal_hops):
                # the controller moved the job OFF this shard: the wait
                # here indicts the original placement decision
                sec["placement"] += dur
            elif any(abs(h.time - s.start) <= _EPS and h.dst == s.shard
                     for h in steal_hops):
                sec["steal_hop"] += dur
            else:
                sec["queue_wait"] += dur
        elif s.phase == INIT:
            sec["crash_rework" if s.truncated else "cold_start"] += dur
        elif s.phase == RUNNING:
            if s.truncated:
                sec["crash_rework"] += dur
            elif i == final_run:
                # the engine scales the whole attempt duration by the
                # shard speed at start: tax = wall * (1 - 1/factor)
                a_start = s.start
                if (i > 0 and spans[i - 1].phase == INIT
                        and not spans[i - 1].truncated
                        and abs(spans[i - 1].end - s.start) <= _EPS):
                    a_start = spans[i - 1].start
                factor = _speed_at(slow, s.shard, a_start)
                tax = 0.0
                if factor > 1.0:
                    tax = (s.end - a_start) * (1.0 - 1.0 / factor)
                tax = min(max(tax, 0.0), dur)
                sec["slowdown"] += tax
                sec[EXEC] += dur - tax
            else:
                sec[EXEC] += dur
        cursor = max(cursor, s.end)
    # fold any float sliver into exec so the tiling is exact
    sec[EXEC] += (end - t0) - sum(sec.values())
    return sec, t0, end


def _blame(sec: Dict[str, float], allowance: float) -> Dict[str, float]:
    blame: Dict[str, float] = {}
    left = max(allowance, 0.0)
    for cat in _CONSUME_ORDER:
        v = sec.get(cat, 0.0)
        used = min(left, v)
        left -= used
        blame[cat] = v - used
    return blame


def _primary(blame: Dict[str, float]) -> str:
    order = CAUSES + (EXEC,)
    return max(order, key=lambda c: (blame.get(c, 0.0), -order.index(c)))


def analyze(timelines, audit=None) -> ForensicsReport:
    """Blame every violated/shed job and roll the fleet up.

    ``timelines`` is a :class:`~repro.obs.spans.TimelineRecorder`, a
    dict, or a list of :class:`JobTimeline` — live or reloaded from a
    JSONL export; ``audit`` an :class:`~repro.obs.audit.AuditLog` or a
    list of entries (used for slowdown factors and the placement
    indictment; omitting it zeroes ``slowdown`` but keeps the
    reconciliation invariant — the seconds stay in ``exec``)."""
    tls = _timelines_list(timelines)
    entries = _audit_entries(audit)
    slow = _slow_windows(entries)
    report = ForensicsReport(
        totals={c: 0.0 for c in CAUSES + (EXEC,)},
        primary_counts={})
    for tl in tls:
        if tl.violated is not True or tl.reject_reason is not None:
            continue
        if not tl.spans or tl.spans[-1].end is None:
            continue               # open lifecycle: finalize() first
        sec, t0, end = _decompose(tl, slow)
        shed = tl.shed_reason is not None
        if shed:
            # no finite finish: every observed second was wasted
            overrun = end - t0
        else:
            overrun = max(end - tl.deadline, 0.0)
        allowance = (end - t0) - overrun
        blame = _blame(sec, allowance)
        primary = _primary(blame)
        indicts = None
        if blame.get("placement", 0.0) > _EPS:
            for e in entries:
                if (e.job_id == tl.job_id
                        and e.action in (JOB_STOLEN, DRAIN)):
                    indicts = {"time": e.time, "action": e.action,
                               "shard": e.shard, "detail": e.detail}
                    break
        jb = JobBlame(
            job_id=tl.job_id, tenant=tl.tenant, slo_class=tl.slo_class,
            shard=tl.shard, submit_time=tl.submit_time, start=t0,
            deadline=tl.deadline, end=end, overrun_s=overrun, shed=shed,
            retries=tl.retries, hops=len(tl.hops), seconds=sec,
            blame=blame, primary_cause=primary, indicts=indicts)
        report.jobs.append(jb)
        report.violated += 1
        if shed:
            report.shed += 1
        else:
            report.completed_late += 1
        for c, v in blame.items():
            report.totals[c] = report.totals.get(c, 0.0) + v
        report.primary_counts[primary] = (
            report.primary_counts.get(primary, 0) + 1)
    return report
