"""Elastic-decision audit log: every control action with the signals
that justified it.

SLO-Guard's premise — SLO-constrained autotuning is only trustworthy
when every decision is attributable to recorded signals — applied to
our control plane: each steal / resize / rejection / reclaim the
:class:`~repro.cluster.elastic.ElasticController` performs is recorded
as an :class:`AuditEntry` carrying the :class:`~repro.cluster.health.
ShardHealth` snapshot(s) the controller *acted on* (captured before the
action mutated the fleet, not re-derived after the fact). "Why did
shard 3 shrink at t=812?" is then answerable from the artifact::

    for e in audit.explain(shard=3, t=812.0):
        print(e.time, e.action, e.detail, e.inputs)

The log is a passive sink: the controller only writes into it when one
is attached (``Telemetry.attach`` does this), so un-instrumented runs
pay nothing.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.cluster.health import ShardHealth

# Audit action tags mirror the fabric event kinds they pair with, plus
# the reclaim action (which has no fabric event — it is pure billing
# upkeep inside a control cycle).
STEAL, RESIZE, REJECT, RECLAIM = ("job_stolen", "shard_resized",
                                  "job_rejected", "idle_reclaim")


def health_dict(h: ShardHealth) -> Dict[str, float]:
    """A ShardHealth snapshot as a JSON-able dict, including the derived
    pressure/free-capacity signals the controller thresholds on."""
    d = dataclasses.asdict(h)
    d["pressure"] = h.pressure
    d["free_capacity"] = h.free_capacity
    return d


@dataclass(frozen=True)
class AuditEntry:
    """One recorded control decision.

    ``inputs`` maps a role name (``"src"`` / ``"dst"`` for steals,
    ``"shard"`` for resizes and reclaims, ``"fleet"`` for rejections)
    to the ShardHealth dict(s) the decision read. ``shard`` is the
    primary acted-on shard (receiver for steals, resized shard for
    resizes, -1 for fleet-level rejections)."""

    time: float
    action: str
    shard: int
    job_id: Optional[int] = None
    tenant: Optional[str] = None
    detail: str = ""
    inputs: Dict[str, object] = field(default_factory=dict)

    def to_dict(self) -> Dict:
        return {"type": "audit", "time": self.time, "action": self.action,
                "shard": self.shard, "job_id": self.job_id,
                "tenant": self.tenant, "detail": self.detail,
                "inputs": self.inputs}

    @classmethod
    def from_dict(cls, d: Dict) -> "AuditEntry":
        return cls(time=float(d["time"]), action=d["action"],
                   shard=int(d["shard"]), job_id=d.get("job_id"),
                   tenant=d.get("tenant"), detail=d.get("detail", ""),
                   inputs=d.get("inputs", {}))


class AuditLog:
    """Append-only decision record with time/shard/action queries."""

    def __init__(self) -> None:
        self.entries: List[AuditEntry] = []

    def record(self, entry: AuditEntry) -> None:
        self.entries.append(entry)

    def decision(self, *, time: float, action: str, shard: int,
                 job_id: Optional[int] = None,
                 tenant: Optional[str] = None, detail: str = "",
                 inputs: Optional[Dict[str, object]] = None) -> AuditEntry:
        """Build-and-record convenience used by the ElasticController.
        ``inputs`` values may be :class:`ShardHealth` snapshots (converted
        to dicts) or anything already JSON-able. The controller only
        duck-types this sink, so :mod:`repro.cluster.elastic` carries no
        import-time dependency on the obs package."""
        conv: Dict[str, object] = {}
        for role, v in (inputs or {}).items():
            conv[role] = health_dict(v) if isinstance(v, ShardHealth) else v
        entry = AuditEntry(time=time, action=action, shard=shard,
                           job_id=job_id, tenant=tenant, detail=detail,
                           inputs=conv)
        self.record(entry)
        return entry

    def __len__(self) -> int:
        return len(self.entries)

    # -- queries -------------------------------------------------------------

    def query(self, *, action: Optional[str] = None,
              shard: Optional[int] = None,
              job_id: Optional[int] = None,
              t0: float = float("-inf"),
              t1: float = float("inf")) -> List[AuditEntry]:
        """Entries matching every given filter, in record order."""
        out = []
        for e in self.entries:
            if action is not None and e.action != action:
                continue
            if shard is not None and e.shard != shard:
                continue
            if job_id is not None and e.job_id != job_id:
                continue
            if not t0 <= e.time <= t1:
                continue
            out.append(e)
        return out

    def explain(self, *, shard: int, t: float,
                around: float = 30.0) -> List[AuditEntry]:
        """The decisions touching ``shard`` within ``around`` seconds of
        ``t`` — the "why did shard 3 shrink at t=812?" query."""
        return self.query(shard=shard, t0=t - around, t1=t + around)

    # -- export --------------------------------------------------------------

    def to_dicts(self) -> List[Dict]:
        return [e.to_dict() for e in self.entries]
