"""SLO-attainment / queue-depth time-series report.

Renders a per-sim-time-bucket table from recorded telemetry — the
"when did we start violating, and what was the queue doing" view the
end-of-run summary cannot give::

    == SLO attainment over time (bucket=60s) ==
    t[s]        sub  done  viol  attain%  wait_s    p50    p95    p99  qdepth  steals  resz
    0-60         41    12     0    100.0     1.2    1.0    4.1    8.2     3.1       0     0
    ...

With metric rows, p50/p95/p99 are per-bucket queue-wait quantiles from
the exported ``queue_wait_s`` histogram windows (log-bucket upper
bounds); chaos runs additionally get a ``shed`` column counting
truncated lifecycles (JOB_SHED / cancel_running) at their shed
instant, kept out of the completed/attainment math.

The report is computed purely from exported data — a list of
:class:`~repro.obs.spans.JobTimeline` (or a recorder / dict of them)
plus optional metric rows as produced by
``MetricsRegistry.to_dicts()`` — so a JSONL export reloaded with
:func:`repro.obs.export.read_jsonl` renders the *identical* report
(that round-trip is pinned in ``tests/test_obs.py``).
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional

from repro.obs.spans import QUEUED, JobTimeline


def _timelines_list(timelines) -> List[JobTimeline]:
    from repro.obs.spans import TimelineRecorder

    if isinstance(timelines, TimelineRecorder):
        return [tl for _, tl in sorted(timelines.timelines().items())]
    if isinstance(timelines, dict):
        return [tl for _, tl in sorted(timelines.items())]
    return sorted(timelines, key=lambda tl: tl.job_id)


def _series_name(series: str) -> str:
    return series.split("{", 1)[0]


def _counter_bucket_deltas(rows: List[Dict], name: str, bucket: float,
                           n_buckets: int) -> List[float]:
    """Per-bucket increments of a cumulative counter, summed across all
    label sets, attributed by window midpoint."""
    out = [0.0] * n_buckets
    per_series: Dict[str, List[Dict]] = {}
    for r in rows:
        if _series_name(r["series"]) == name:
            per_series.setdefault(r["series"], []).append(r)
    for series_rows in per_series.values():
        series_rows.sort(key=lambda r: r["window_end"])
        prev = 0.0
        for r in series_rows:
            cur = float(r.get("value", prev))
            mid = (float(r["window_start"]) + float(r["window_end"])) / 2.0
            b = min(int(mid // bucket), n_buckets - 1)
            out[b] += cur - prev
            prev = cur
    return out


def _histogram_bucket_quantiles(rows: List[Dict], name: str, bucket: float,
                                n_buckets: int,
                                qs=(0.5, 0.95, 0.99)) -> List[Dict]:
    """Per-report-bucket quantiles of a histogram series, merged across
    label sets. Exported histogram windows are cumulative, so each
    window's bucket-count deltas against the previous window are
    attributed by window midpoint (like counter deltas); the quantile
    value is the log-bucket upper bound (``base * 2**index``) — an
    upper-bound estimate with a factor-of-2 relative error, same as
    ``Histogram.quantile``. Returns one ``{q: value | None}`` dict per
    report bucket (None where nothing was observed)."""
    per_series: Dict[str, List[Dict]] = {}
    for r in rows:
        if _series_name(r["series"]) == name and "buckets" in r:
            per_series.setdefault(r["series"], []).append(r)
    # (histogram base, log-bucket index) -> observation count, per
    # report bucket; keyed with base so mixed-base series still merge
    acc: List[Dict] = [{} for _ in range(n_buckets)]
    for series_rows in per_series.values():
        series_rows.sort(key=lambda r: r["window_end"])
        prev: Dict[int, int] = {}
        for r in series_rows:
            cur = {int(k): int(v)
                   for k, v in (r.get("buckets") or {}).items()}
            base = float(r.get("base", 0.001))
            mid = (float(r["window_start"]) + float(r["window_end"])) / 2.0
            b = min(int(mid // bucket), n_buckets - 1)
            for idx, c in cur.items():
                d = c - prev.get(idx, 0)
                if d > 0:
                    acc[b][(base, idx)] = acc[b].get((base, idx), 0) + d
            prev = cur
    out: List[Dict] = []
    for counts in acc:
        total = sum(counts.values())
        if not total:
            out.append({q: None for q in qs})
            continue
        items = sorted((base * (2.0 ** idx), c)
                       for (base, idx), c in counts.items())
        row: Dict = {}
        for q in qs:
            rank = q * total
            seen = 0
            val = items[-1][0]
            for ub, c in items:
                seen += c
                if seen >= rank:
                    val = ub
                    break
            row[q] = val
        out.append(row)
    return out


def _gauge_bucket_stats(rows: List[Dict], name: str, bucket: float,
                        n_buckets: int) -> List[Optional[float]]:
    """Mean of a gauge summed across shards, per bucket (None where no
    window falls in the bucket)."""
    # window_end -> {series: value}; sum across series per window, then
    # average the per-window sums that land in each bucket.
    per_window: Dict[float, float] = {}
    for r in rows:
        if _series_name(r["series"]) != name:
            continue
        key = float(r["window_end"])
        per_window[key] = per_window.get(key, 0.0) + float(r.get("value", 0.0))
    sums = [0.0] * n_buckets
    counts = [0] * n_buckets
    for end, v in per_window.items():
        b = min(int(max(end - 1e-9, 0.0) // bucket), n_buckets - 1)
        sums[b] += v
        counts[b] += 1
    return [sums[i] / counts[i] if counts[i] else None
            for i in range(n_buckets)]


def report_rows(timelines, metric_rows: Optional[Iterable[Dict]] = None,
                *, bucket: float = 60.0) -> List[Dict]:
    """The report as data: one dict per time bucket."""
    if bucket <= 0:
        raise ValueError(f"bucket must be > 0 seconds, got {bucket}")
    tls = _timelines_list(timelines)
    rows = list(metric_rows) if metric_rows is not None else []
    horizon = 0.0
    for tl in tls:
        horizon = max(horizon, tl.submit_time)
        fin = tl.finish
        if fin is not None:
            horizon = max(horizon, fin)
    for r in rows:
        horizon = max(horizon, float(r.get("window_end", 0.0)))
    n = max(1, math.ceil((horizon + 1e-9) / bucket))

    out = [{"t0": i * bucket, "t1": (i + 1) * bucket, "submitted": 0,
            "completed": 0, "violated": 0, "rejected": 0, "shed": 0,
            "wait_s_sum": 0.0, "queue_depth": None,
            "wait_p50": None, "wait_p95": None, "wait_p99": None,
            "steals": 0.0, "resizes": 0.0}
           for i in range(n)]

    def bucket_of(t: float) -> int:
        return min(int(t // bucket), n - 1)

    for tl in tls:
        if tl.reject_reason is not None:
            out[bucket_of(tl.submit_time)]["rejected"] += 1
            continue
        out[bucket_of(tl.submit_time)]["submitted"] += 1
        if tl.shed_reason is not None:
            # truncated lifecycle (JOB_SHED / cancel_running): its own
            # terminal column, bucketed at the shed instant, so chaos
            # reports reconcile with the bench's chaos_verdict
            end = tl.submit_time
            for s in tl.spans:
                if s.end is not None:
                    end = max(end, s.end)
            out[bucket_of(end)]["shed"] += 1
            continue
        fin = tl.finish
        if fin is None:
            continue
        b = out[bucket_of(fin)]
        b["completed"] += 1
        if tl.violated:
            b["violated"] += 1
        b["wait_s_sum"] += tl.phase_seconds(QUEUED)

    if rows:
        qdepth = _gauge_bucket_stats(rows, "queue_depth", bucket, n)
        steals = _counter_bucket_deltas(rows, "steals", bucket, n)
        resizes = _counter_bucket_deltas(rows, "resizes", bucket, n)
        waits = _histogram_bucket_quantiles(rows, "queue_wait_s", bucket, n)
        for i, b in enumerate(out):
            b["queue_depth"] = qdepth[i]
            b["steals"] = steals[i]
            b["resizes"] = resizes[i]
            b["wait_p50"] = waits[i][0.5]
            b["wait_p95"] = waits[i][0.95]
            b["wait_p99"] = waits[i][0.99]
    return out


def render_report(timelines, metric_rows: Optional[Iterable[Dict]] = None,
                  *, bucket: float = 60.0,
                  title: str = "SLO attainment over time") -> str:
    """The human-readable per-bucket table plus a totals footer."""
    tls = _timelines_list(timelines)
    rows = report_rows(tls, metric_rows, bucket=bucket)
    have_metrics = any(r["queue_depth"] is not None for r in rows)
    have_shed = any(r["shed"] for r in rows)

    header = (f"{'t[s]':>11s} {'sub':>5s} {'done':>5s} {'viol':>5s} "
              f"{'attain%':>8s} {'wait_s':>7s}")
    if have_shed:
        header += f" {'shed':>5s}"
    if have_metrics:
        header += (f" {'p50':>6s} {'p95':>6s} {'p99':>6s}"
                   f" {'qdepth':>7s} {'steals':>6s} {'resz':>5s}")
    lines = [f"== {title} (bucket={bucket:g}s) ==", header]

    def q(v) -> str:
        return f"{v:>6.1f}" if v is not None else f"{'-':>6s}"

    for r in rows:
        if not (r["submitted"] or r["completed"] or r["rejected"]
                or r["shed"] or (r["queue_depth"] or 0)
                or r["steals"] or r["resizes"]):
            continue
        done = r["completed"]
        attain = 100.0 * (1.0 - r["violated"] / done) if done else float("nan")
        wait = r["wait_s_sum"] / done if done else float("nan")
        line = (f"{r['t0']:5.0f}-{r['t1']:<5.0f} {r['submitted']:>5d} "
                f"{done:>5d} {r['violated']:>5d} "
                f"{attain:>8.1f} {wait:>7.1f}")
        if have_shed:
            line += f" {r['shed']:>5d}"
        if have_metrics:
            qd = r["queue_depth"]
            line += (f" {q(r['wait_p50'])} {q(r['wait_p95'])} "
                     f"{q(r['wait_p99'])}"
                     f" {qd if qd is not None else float('nan'):>7.1f} "
                     f"{r['steals']:>6.0f} {r['resizes']:>5.0f}")
        lines.append(line)

    done = sum(r["completed"] for r in rows)
    viol = sum(r["violated"] for r in rows)
    rej = sum(r["rejected"] for r in rows)
    shed = sum(r["shed"] for r in rows)
    sub = sum(r["submitted"] for r in rows)
    open_jobs = sub - done - shed
    attain = 100.0 * (1.0 - viol / done) if done else 100.0
    foot = (f"total: {sub} submitted, {done} completed, {viol} violated "
            f"(attainment {attain:.1f}%)")
    if rej:
        foot += f", {rej} rejected"
    if shed:
        foot += f", {shed} shed"
    if open_jobs:
        foot += f", {open_jobs} never completed"
    lines.append(foot)
    return "\n".join(lines)
