"""Kimi K2 — trillion-parameter MoE, 384 experts top-8 [arXiv:2501.kimi2]."""
from repro.config import MLAConfig, ModelConfig, MoEConfig
from repro.configs import register


@register
def kimi_k2_1t_a32b() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        arch_type="moe",
        source="Kimi K2 — trillion-param MoE, DeepSeek-V3-style MLA "
               "(kv_lora=512, 64 heads) [arXiv:2501.kimi2]",
        num_layers=61,
        d_model=7168,
        num_heads=64,
        num_kv_heads=64,         # MLA: one latent head decompressed per head
        head_dim=128,
        d_ff=2048,               # per-expert hidden dim
        vocab_size=163840,
        max_seq_len=131072,
        attention="mla",
        mla=MLAConfig(
            kv_lora_rank=512,
            q_lora_rank=1536,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            num_experts=384,
            top_k=8,
            num_shared_experts=1,
            d_ff_expert=2048,
            first_dense_layers=1,
        ),
        norm="rmsnorm",
        activation="swiglu",
        tie_embeddings=False,
    )
