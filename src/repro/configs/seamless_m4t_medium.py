"""SeamlessM4T-medium — enc-dec multimodal (audio) [arXiv:2308.11596].

Backbone only: the mel-spectrogram + conv feature extractor is a STUB —
``input_specs`` provides precomputed frame embeddings (B, frames, 1024)."""
from repro.config import EncDecConfig, FrontendConfig, ModelConfig
from repro.configs import register


@register
def seamless_m4t_medium() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        arch_type="audio",
        source="enc-dec, multimodal [arXiv:2308.11596]",
        num_layers=12,            # decoder layers
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=256206,
        max_seq_len=4096,
        norm="layernorm",
        activation="gelu",
        encdec=EncDecConfig(num_encoder_layers=12, encoder_seq_len=1024),
        frontend=FrontendConfig(kind="audio", num_embeddings=1024, embed_dim=1024),
        tie_embeddings=True,
    )
