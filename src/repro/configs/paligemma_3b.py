"""PaliGemma-3B — SigLIP + Gemma VLM [arXiv:2407.07726].

Backbone only: the SigLIP vision tower is a STUB — ``input_specs``
provides precomputed patch embeddings (B, 256, 1152) that the model
projects and prepends to the text sequence."""
from repro.config import FrontendConfig, ModelConfig
from repro.configs import register


@register
def paligemma_3b() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b",
        arch_type="vlm",
        source="SigLIP + gemma [arXiv:2407.07726]",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=257216,
        max_seq_len=8192,
        norm="rmsnorm",
        activation="gelu",
        frontend=FrontendConfig(kind="vision", num_embeddings=256, embed_dim=1152),
        tie_embeddings=True,
    )
