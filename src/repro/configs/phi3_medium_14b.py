"""Phi-3-medium 14B — dense, RoPE SwiGLU GQA [arXiv:2404.14219]."""
from repro.config import ModelConfig
from repro.configs import register


@register
def phi3_medium_14b() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b",
        arch_type="dense",
        source="RoPE SwiGLU GQA [arXiv:2404.14219]",
        num_layers=40,
        d_model=5120,
        num_heads=40,
        num_kv_heads=10,
        d_ff=17920,
        vocab_size=100352,
        max_seq_len=131072,
        norm="rmsnorm",
        activation="swiglu",
        tie_embeddings=False,
    )
