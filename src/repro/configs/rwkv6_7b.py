"""RWKV6 'Finch' 7B — attention-free, data-dependent decay [arXiv:2404.05892]."""
from repro.config import ModelConfig, SSMConfig
from repro.configs import register


@register
def rwkv6_7b() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        arch_type="ssm",
        source="Finch — data-dependent decay [arXiv:2404.05892]",
        num_layers=32,
        d_model=4096,
        num_heads=64,            # 4096 / state_size 64
        num_kv_heads=64,
        d_ff=14336,
        vocab_size=65536,
        max_seq_len=1 << 20,     # recurrent: unbounded context
        attention="none",
        ssm=SSMConfig(kind="rwkv6", state_size=64, chunk_size=128),
        norm="rmsnorm",
        activation="swiglu",
        tie_embeddings=False,
    )
