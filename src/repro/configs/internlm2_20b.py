"""InternLM2-20B — dense GQA [arXiv:2403.17297]."""
from repro.config import ModelConfig
from repro.configs import register


@register
def internlm2_20b() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b",
        arch_type="dense",
        source="GQA [arXiv:2403.17297]",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=16384,
        vocab_size=92544,
        max_seq_len=32768,
        norm="rmsnorm",
        activation="swiglu",
        tie_embeddings=False,
    )
