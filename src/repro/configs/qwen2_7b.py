"""Qwen2-7B — dense GQA with QKV bias [arXiv:2407.10671]."""
from repro.config import ModelConfig
from repro.configs import register


@register
def qwen2_7b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b",
        arch_type="dense",
        source="GQA, QKV bias [arXiv:2407.10671]",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        max_seq_len=131072,
        norm="rmsnorm",
        activation="swiglu",
        qkv_bias=True,
        tie_embeddings=False,
    )
