"""Zamba2-7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from repro.config import HybridConfig, ModelConfig, SSMConfig
from repro.configs import register


@register
def zamba2_7b() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        arch_type="hybrid",
        source="Mamba2 + shared attn blocks [arXiv:2411.15242]",
        num_layers=81,
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        head_dim=112,
        d_ff=14336,              # shared block MLP width
        vocab_size=32000,
        max_seq_len=1 << 20,
        ssm=SSMConfig(kind="mamba2", state_size=64, chunk_size=128, expand=2),
        hybrid=HybridConfig(attn_every=6, shared_attn=True),
        norm="rmsnorm",
        activation="swiglu",
        tie_embeddings=True,
    )
