"""GPT2-Large — paper's own evaluation model."""
from repro.config import ModelConfig
from repro.configs import register


@register
def gpt2_large() -> ModelConfig:
    return ModelConfig(
        name="gpt2-large",
        arch_type="dense",
        source="[18] GPT-2; paper §6.1",
        num_layers=36,
        d_model=1280,
        num_heads=20,
        num_kv_heads=20,
        d_ff=5120,
        vocab_size=50257,
        max_seq_len=1024,
        norm="layernorm",
        activation="gelu",
        qkv_bias=True,
        tie_embeddings=True,
    )
