"""DeepSeek-V2 236B — MLA + fine-grained MoE [arXiv:2405.04434]."""
from repro.config import MLAConfig, ModelConfig, MoEConfig
from repro.configs import register


@register
def deepseek_v2_236b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        arch_type="moe",
        source="MLA kv_lora=512, 2 shared+160 routed top-6 [arXiv:2405.04434]",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,
        d_ff=1536,               # per-expert hidden dim
        vocab_size=102400,
        max_seq_len=131072,
        attention="mla",
        mla=MLAConfig(
            kv_lora_rank=512,
            q_lora_rank=1536,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            num_experts=160,
            top_k=6,
            num_shared_experts=2,
            d_ff_expert=1536,
            first_dense_layers=1,
        ),
        norm="rmsnorm",
        activation="swiglu",
        tie_embeddings=False,
    )
