"""Command R+ 104B — dense GQA, no-bias, parallel block
[hf:CohereForAI/c4ai-command-r-v01]."""
from repro.config import ModelConfig
from repro.configs import register


@register
def command_r_plus_104b() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b",
        arch_type="dense",
        source="GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01]",
        num_layers=64,
        d_model=12288,
        num_heads=96,
        num_kv_heads=8,
        d_ff=33792,
        vocab_size=256000,
        max_seq_len=131072,
        norm="layernorm",
        activation="swiglu",
        parallel_block=True,
        qkv_bias=False,
        tie_embeddings=True,
    )
