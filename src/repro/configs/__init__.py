"""Architecture registry: ``get_config(arch_id)`` returns the FULL assigned
configuration; ``smoke_config(arch_id)`` returns a reduced variant of the
same family (<=2-4 layers, d_model<=512, <=4 experts) for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, List

from repro.config import ModelConfig, MoEConfig, SSMConfig

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}

_MODULES = [
    "gpt2_base",
    "gpt2_large",
    "vicuna_7b",
    "rwkv6_7b",
    "seamless_m4t_medium",
    "paligemma_3b",
    "deepseek_v2_236b",
    "phi3_medium_14b",
    "zamba2_7b",
    "command_r_plus_104b",
    "qwen2_7b",
    "internlm2_20b",
    "kimi_k2_1t_a32b",
]

ASSIGNED_ARCHS: List[str] = [
    "rwkv6-7b",
    "seamless-m4t-medium",
    "paligemma-3b",
    "deepseek-v2-236b",
    "phi3-medium-14b",
    "zamba2-7b",
    "command-r-plus-104b",
    "qwen2-7b",
    "internlm2-20b",
    "kimi-k2-1t-a32b",
]

PAPER_ARCHS: List[str] = ["gpt2-base", "gpt2-large", "vicuna-7b"]


def register(fn: Callable[[], ModelConfig]) -> Callable[[], ModelConfig]:
    cfg = fn()
    _REGISTRY[cfg.name] = fn
    return fn


def _ensure_loaded() -> None:
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    _ensure_loaded()
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch]()


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family variant: 2-4 layers, d_model<=512, <=4 experts."""
    cfg = get_config(arch)
    kw: dict = dict(
        d_model=256,
        num_heads=4,
        num_kv_heads=min(cfg.kv_heads(), 2),
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        max_seq_len=256,
        num_layers=2,
        dtype="float32",
        param_dtype="float32",
        remat=False,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=4,
            top_k=2,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            d_ff_expert=128,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
        )
        kw["num_layers"] = 3  # 1 dense + 2 moe
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, state_size=32, num_heads=0, chunk_size=16, expand=2
        )
        kw["num_heads"] = 8 if cfg.arch_type == "ssm" else 4  # rwkv: d/state
    if cfg.hybrid is not None:
        kw["num_layers"] = 5
        kw["hybrid"] = dataclasses.replace(cfg.hybrid, attn_every=2)
    if cfg.encdec is not None:
        kw["encdec"] = dataclasses.replace(
            cfg.encdec, num_encoder_layers=2, encoder_seq_len=16
        )
    if cfg.frontend.kind != "none":
        kw["frontend"] = dataclasses.replace(
            cfg.frontend, num_embeddings=8, embed_dim=48
        )
    if cfg.mla is not None:
        kw["mla"] = dataclasses.replace(
            cfg.mla,
            kv_lora_rank=64,
            q_lora_rank=48,
            qk_nope_head_dim=32,
            qk_rope_head_dim=16,
            v_head_dim=32,
        )
    return cfg.with_overrides(**kw)
