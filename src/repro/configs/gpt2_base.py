"""GPT2-Base — paper's own evaluation model [Brown et al. / Radford et al.]."""
from repro.config import ModelConfig
from repro.configs import register


@register
def gpt2_base() -> ModelConfig:
    return ModelConfig(
        name="gpt2-base",
        arch_type="dense",
        source="[18] GPT-2; paper §6.1",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=50257,
        max_seq_len=1024,
        norm="layernorm",
        activation="gelu",
        qkv_bias=True,
        tie_embeddings=True,
    )
