"""Vicuna-7B — paper's own evaluation model [24] (LLaMA-architecture)."""
from repro.config import ModelConfig
from repro.configs import register


@register
def vicuna_7b() -> ModelConfig:
    return ModelConfig(
        name="vicuna-7b",
        arch_type="dense",
        source="[24] Vicuna (LLaMA arch); paper §6.1",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        d_ff=11008,
        vocab_size=32000,
        max_seq_len=4096,
        norm="rmsnorm",
        activation="swiglu",
        tie_embeddings=False,
    )
