"""Flash attention (GQA, causal / sliding-window, KV-cache aware).

Online-softmax attention that streams KV tiles through VMEM — the
(S, L) score matrix never reaches HBM. This is the TPU-native fix for
the dominant memory-roofline term found in the dry-run baselines (the
XLA blockwise path in ``repro.models.attention`` spills per-block score
tensors to HBM between fusions).

Layout (head-major so each grid cell owns one (batch, head) pair):
  q (B, H,  S, hd)     k,v (B, Hkv, L, hd)     GQA: kv head = h // (H//Hkv)
Grid (B, H, nq, nk): the KV tile index is the minor (fastest) dimension;
VMEM scratch carries (m, l, acc) across KV tiles of one q tile.

Masking is positional: q row i has absolute position ``q_offset + i``
(soft prompt / frontend tokens shift query positions), KV column j has
position j; ``kv_len`` (dynamic, SMEM) marks the valid cache prefix.

TPU sizing: default tiles bq = bk = 512, hd <= 256: live set
q (512, hd) + k/v (512, hd) + scores (512, 512) f32 ~= 2.3 MB at
hd = 128 bf16 — comfortably inside VMEM, MXU dims 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale, causal, window, q_offset, bq, bk):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                   # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                   # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                             # (bq, bk)

    qpos = q_offset + iq * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = kpos < kvlen_ref[0]
    if causal:
        ok &= kpos <= qpos
    if window and window > 0:
        ok &= kpos > qpos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "bq", "bk", "interpret"),
)
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    kv_len=None, causal: bool = True, window: int = 0,
                    q_offset: int = 0, bq: int = 512, bk: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B,H,S,hd); k,v: (B,Hkv,L,hd) -> (B,H,S,hd).

    ``kv_len``: dynamic valid-cache length (defaults to L)."""
    B, H, S, hd = q.shape
    Hkv, L = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    G = H // Hkv
    bq = min(bq, S)
    bk = min(bk, L)
    qpad, kpad = (-S) % bq, (-L) % bk
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, qpad), (0, 0)))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, kpad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, kpad), (0, 0)))
    Sp, Lp = S + qpad, L + kpad
    if kv_len is None:
        kv_len = L
    kv_len = jnp.asarray(kv_len, jnp.int32).reshape(1)
    grid = (B, H, Sp // bq, Lp // bk)
    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=1.0 / (hd ** 0.5), causal=causal, window=window,
            q_offset=q_offset, bq=bq, bk=bk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),        # kv_len (1,)
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),               # running max
            pltpu.VMEM((bq,), jnp.float32),               # running sum
            pltpu.VMEM((bq, hd), jnp.float32),            # accumulator
        ],
        interpret=interpret,
    )(kv_len, q, k, v)
    return out[:, :, :S]
