"""Jit'd wrappers around the Pallas kernels, in MODEL layouts.

On CPU (this container) the kernels execute with ``interpret=True``;
on TPU they compile to Mosaic. ``INTERPRET`` is resolved once from the
backend so callers never pass it explicitly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.mla_decode import mla_decode
from repro.kernels.rwkv_wkv import rwkv6_wkv
from repro.kernels.score_ce import score_ce

MAX_HEAD_DIM = 256   # VMEM tiling budget of the flash kernels


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_score_ce(hidden, emb, labels, mask, *, bt: int = 256,
                   bv: int = 512):
    """Eqn-1 scoring in model layout: hidden (B,S,d), labels/mask (B,S).

    Returns (mean_loss, per_example (B,)). The vocab tile is shrunk to a
    divisor of V rather than padding the embedding (padded vocab rows
    would distort the logsumexp)."""
    B, S, d = hidden.shape
    V = emb.shape[0]
    # pick the largest tile <= bv that divides V (V here is always a
    # multiple of 128 for the assigned archs; testbed vocabs are small)
    while V % bv != 0:
        bv //= 2
        if bv < 8:
            bv = V          # fall back: single tile
            break
    nll = score_ce(hidden.reshape(B * S, d), emb, labels.reshape(-1),
                   bt=bt, bv=bv, interpret=_interpret())
    nll = nll.reshape(B, S) * mask
    tok = jnp.maximum(mask.sum(axis=-1), 1.0)
    per_ex = nll.sum(axis=-1) / tok
    mean = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return mean, per_ex


def gqa_flash(q, k, v, *, causal=True, window=0, q_offset=0, kv_len=None,
              bq: int = 512, bk: int = 512):
    """Model layout adapter: q (B,S,H,hd), k/v (B,L,Hkv,hd) ->
    (B,S,H,hd).

    Ergonomics the raw kernel doesn't provide: head dims over the VMEM
    tiling budget raise here (instead of a Mosaic shape error deep in
    the Pallas call), and a KV length that is not a lane multiple of 128
    is zero-padded with ``kv_len`` masking the tail — the kernel then
    always sees 128-aligned tiles."""
    hd = q.shape[-1]
    if hd > MAX_HEAD_DIM:
        raise ValueError(
            f"gqa_flash: head_dim={hd} exceeds the flash kernel's VMEM "
            f"tiling budget ({MAX_HEAD_DIM}); use "
            "repro.models.attention.scaled_attention for this shape")
    L = k.shape[1]
    pad = (-L) % 128
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # mask the padded tail; honor a tighter caller-supplied kv_len
        kv_len = L if kv_len is None else jnp.minimum(
            jnp.asarray(kv_len, jnp.int32), L)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          q_offset=q_offset, kv_len=kv_len, bq=bq, bk=bk,
                          interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


def gqa_flash_decode(q, k, v, *, kv_len=None, q_pos=None, window=0,
                     splits: int = 8, bk: int = 256):
    """Single-token decode adapter: q (B,1,H,hd) or (B,H,hd),
    k/v (B,L,Hkv,hd) -> same rank as q.

    ``kv_len`` / ``q_pos`` are dynamic scalars (contiguous-prefix cache
    convention; see ``flash_decode``)."""
    squeeze = q.ndim == 4
    if squeeze:
        assert q.shape[1] == 1, "decode takes exactly one query token"
        q = q[:, 0]
    if q.shape[-1] > MAX_HEAD_DIM:
        raise ValueError(
            f"gqa_flash_decode: head_dim={q.shape[-1]} exceeds the flash "
            f"kernel's VMEM tiling budget ({MAX_HEAD_DIM})")
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_decode(q, kt, vt, kv_len=kv_len, q_pos=q_pos, window=window,
                       splits=splits, bk=bk, interpret=_interpret())
    return out[:, None] if squeeze else out


def mla_flash_decode(q_lat, q_pe, ckv, kpe, *, scale, kv_len=None,
                     q_pos=None, splits: int = 8, bk: int = 256):
    """Absorbed-MLA decode adapter: q_lat (B,1,H,r) or (B,H,r), q_pe
    likewise, ckv (B,L,r), kpe (B,L,rd) -> latent output, rank of q_lat.

    ``scale`` is 1/sqrt(qk_nope_head_dim + qk_rope_head_dim) — the
    pre-absorption head dim."""
    squeeze = q_lat.ndim == 4
    if squeeze:
        assert q_lat.shape[1] == 1, "decode takes exactly one query token"
        q_lat, q_pe = q_lat[:, 0], q_pe[:, 0]
    out = mla_decode(q_lat, q_pe, ckv, kpe, scale=float(scale),
                     kv_len=kv_len, q_pos=q_pos, splits=splits, bk=bk,
                     interpret=_interpret())
    return out[:, None] if squeeze else out


def wkv(r, k, v, logw, u, state, *, chunk: int = 128):
    """Model layout adapter: r/k/v/logw (B,H,T,hd), u (H,hd),
    state (B,H,hd,hd) -> (y (B,H,T,hd), state')."""
    B, H, T, hd = r.shape
    fl = lambda t: t.reshape(B * H, T, hd)
    uu = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, hd)
    s0 = state.reshape(B * H, hd, hd)
    y, s = rwkv6_wkv(fl(r), fl(k), fl(v), fl(logw), uu, s0, chunk=chunk,
                     interpret=_interpret())
    return y.reshape(B, H, T, hd), s.reshape(B, H, hd, hd)
