"""Jit'd wrappers around the Pallas kernels, in MODEL layouts.

On CPU (this container) the kernels execute with ``interpret=True``;
on TPU they compile to Mosaic. ``INTERPRET`` is resolved once from the
backend so callers never pass it explicitly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv_wkv import rwkv6_wkv
from repro.kernels.score_ce import score_ce


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_score_ce(hidden, emb, labels, mask, *, bt: int = 256,
                   bv: int = 512):
    """Eqn-1 scoring in model layout: hidden (B,S,d), labels/mask (B,S).

    Returns (mean_loss, per_example (B,)). The vocab tile is shrunk to a
    divisor of V rather than padding the embedding (padded vocab rows
    would distort the logsumexp)."""
    B, S, d = hidden.shape
    V = emb.shape[0]
    # pick the largest tile <= bv that divides V (V here is always a
    # multiple of 128 for the assigned archs; testbed vocabs are small)
    while V % bv != 0:
        bv //= 2
        if bv < 8:
            bv = V          # fall back: single tile
            break
    nll = score_ce(hidden.reshape(B * S, d), emb, labels.reshape(-1),
                   bt=bt, bv=bv, interpret=_interpret())
    nll = nll.reshape(B, S) * mask
    tok = jnp.maximum(mask.sum(axis=-1), 1.0)
    per_ex = nll.sum(axis=-1) / tok
    mean = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return mean, per_ex


def gqa_flash(q, k, v, *, causal=True, window=0, q_offset=0, kv_len=None,
              bq: int = 512, bk: int = 512):
    """Model layout adapter: q (B,S,H,hd), k/v (B,L,Hkv,hd) ->
    (B,S,H,hd)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          q_offset=q_offset, kv_len=kv_len, bq=bq, bk=bk,
                          interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


def wkv(r, k, v, logw, u, state, *, chunk: int = 128):
    """Model layout adapter: r/k/v/logw (B,H,T,hd), u (H,hd),
    state (B,H,hd,hd) -> (y (B,H,T,hd), state')."""
    B, H, T, hd = r.shape
    fl = lambda t: t.reshape(B * H, T, hd)
    uu = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, hd)
    s0 = state.reshape(B * H, hd, hd)
    y, s = rwkv6_wkv(fl(r), fl(k), fl(v), fl(logw), uu, s0, chunk=chunk,
                     interpret=_interpret())
    return y.reshape(B, H, T, hd), s.reshape(B, H, hd, hd)
