"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def score_ce_ref(hidden: jax.Array, emb: jax.Array,
                 labels: jax.Array) -> jax.Array:
    """Per-token NLL (T,) f32: full-logits log-softmax gather."""
    logits = (hidden.astype(jnp.float32) @
              emb.astype(jnp.float32).T)                  # (T, V)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return logz - gold


def flash_attention_ref(q, k, v, *, causal=True, window=0, q_offset=0,
                        kv_len=None):
    """q: (B,H,S,hd); k,v: (B,Hkv,L,hd). GQA by head-group mapping.
    q position i attends to kv position j iff
        j <= q_offset + i               (causal)
        j >  q_offset + i - window      (sliding window, if window > 0)
        j <  kv_len                     (cache validity, if given)
    Returns (B,H,S,hd) in q.dtype."""
    B, H, S, hd = q.shape
    Hkv, L = k.shape[1], k.shape[2]
    G = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, S, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhgsd,bhld->bhgsl", qf, kf) / jnp.sqrt(hd)
    qpos = q_offset + jnp.arange(S)
    kpos = jnp.arange(L)
    mask = jnp.ones((S, L), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window and window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    if kv_len is not None:
        mask &= (kpos < kv_len)[None, :]
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgsl,bhld->bhgsd", probs, vf)
    return out.reshape(B, H, S, hd).astype(q.dtype)


def rwkv6_wkv_ref(r, k, v, logw, u, state0):
    """Sequential WKV recurrence (the exact semantics the chunked kernel
    must reproduce).

    r,k,v,logw: (BH, T, hd) f32 (logw <= 0); u: (BH, hd);
    state0: (BH, hd, hd) [key-dim x value-dim].
    Returns (y (BH, T, hd), state (BH, hd, hd)):
        y_t   = r_t @ (S_{t-1} + diag(u) k_t v_t^T)
        S_t   = diag(exp(logw_t)) S_{t-1} + k_t v_t^T
    """
    def step(s, xs):
        rt, kt, vt, wt = xs                               # (BH, hd)
        kv = kt[:, :, None] * vt[:, None, :]              # (BH, hd, hd)
        y = jnp.einsum("bd,bde->be", rt, s + u[:, :, None] * kv)
        s = jnp.exp(wt)[:, :, None] * s + kv
        return s, y

    xs = tuple(t.swapaxes(0, 1) for t in (r, k, v, logw))  # (T, BH, hd)
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1), state
