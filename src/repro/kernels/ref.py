"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def score_ce_ref(hidden: jax.Array, emb: jax.Array,
                 labels: jax.Array) -> jax.Array:
    """Per-token NLL (T,) f32: full-logits log-softmax gather."""
    logits = (hidden.astype(jnp.float32) @
              emb.astype(jnp.float32).T)                  # (T, V)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return logz - gold


def flash_attention_ref(q, k, v, *, causal=True, window=0, q_offset=0,
                        kv_len=None):
    """q: (B,H,S,hd); k,v: (B,Hkv,L,hd). GQA by head-group mapping.
    q position i attends to kv position j iff
        j <= q_offset + i               (causal)
        j >  q_offset + i - window      (sliding window, if window > 0)
        j <  kv_len                     (cache validity, if given)
    Returns (B,H,S,hd) in q.dtype."""
    B, H, S, hd = q.shape
    Hkv, L = k.shape[1], k.shape[2]
    G = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, S, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhgsd,bhld->bhgsl", qf, kf) / jnp.sqrt(hd)
    qpos = q_offset + jnp.arange(S)
    kpos = jnp.arange(L)
    mask = jnp.ones((S, L), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window and window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    if kv_len is not None:
        mask &= (kpos < kv_len)[None, :]
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgsl,bhld->bhgsd", probs, vf)
    return out.reshape(B, H, S, hd).astype(q.dtype)


def flash_decode_ref(q, k, v, *, kv_len=None, q_pos=None, window=0):
    """Single-token GQA decode: q (B,H,hd); k,v (B,Hkv,L,hd) -> (B,H,hd).

    KV column j is attended iff j < kv_len, j <= q_pos (default
    kv_len - 1) and, with a window, j > q_pos - window. ``kv_len`` /
    ``q_pos`` are scalars (dynamic ok) shared across the batch."""
    B, H, hd = q.shape
    Hkv, L = k.shape[1], k.shape[2]
    G = H // Hkv
    if kv_len is None:
        kv_len = L
    if q_pos is None:
        q_pos = kv_len - 1
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, hd)
    scores = jnp.einsum("bhgd,bhld->bhgl", qf, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(hd)
    kpos = jnp.arange(L)
    mask = (kpos < kv_len) & (kpos <= q_pos)
    if window and window > 0:
        mask &= kpos > q_pos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgl,bhld->bhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def mla_decode_ref(q_lat, q_pe, ckv, kpe, *, scale, kv_len=None, q_pos=None):
    """Dense absorbed-MLA decode: q_lat (B,H,r); q_pe (B,H,rd);
    ckv (B,L,r); kpe (B,L,rd) -> (B,H,r) latent output.

        scores = (q_lat @ ckv^T + q_pe @ kpe^T) * scale
        out    = softmax(scores) @ ckv

    Same kv_len / q_pos masking convention as ``flash_decode_ref``."""
    L = ckv.shape[1]
    if kv_len is None:
        kv_len = L
    if q_pos is None:
        q_pos = kv_len - 1
    scores = (
        jnp.einsum("bhr,blr->bhl", q_lat.astype(jnp.float32),
                   ckv.astype(jnp.float32))
        + jnp.einsum("bhp,blp->bhl", q_pe.astype(jnp.float32),
                     kpe.astype(jnp.float32))
    ) * scale
    kpos = jnp.arange(L)
    mask = (kpos < kv_len) & (kpos <= q_pos)
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhl,blr->bhr", probs, ckv.astype(jnp.float32))
    return out.astype(q_lat.dtype)


def rwkv6_wkv_ref(r, k, v, logw, u, state0):
    """Sequential WKV recurrence (the exact semantics the chunked kernel
    must reproduce).

    r,k,v,logw: (BH, T, hd) f32 (logw <= 0); u: (BH, hd);
    state0: (BH, hd, hd) [key-dim x value-dim].
    Returns (y (BH, T, hd), state (BH, hd, hd)):
        y_t   = r_t @ (S_{t-1} + diag(u) k_t v_t^T)
        S_t   = diag(exp(logw_t)) S_{t-1} + k_t v_t^T
    """
    def step(s, xs):
        rt, kt, vt, wt = xs                               # (BH, hd)
        kv = kt[:, :, None] * vt[:, None, :]              # (BH, hd, hd)
        y = jnp.einsum("bd,bde->be", rt, s + u[:, :, None] * kv)
        s = jnp.exp(wt)[:, :, None] * s + kv
        return s, y

    xs = tuple(t.swapaxes(0, 1) for t in (r, k, v, logw))  # (T, BH, hd)
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1), state
