"""RWKV6 chunked WKV kernel (data-dependent decay linear attention).

The Finch recurrence per head (key dim i, value dim j):

    y_t  = r_t @ (S_{t-1} + diag(u) k_t v_t^T)
    S_t  = diag(w_t) S_{t-1} + k_t v_t^T        w_t = exp(logw_t) in (0,1]

The GPU reference implementations are sequential CUDA scans; the
TPU-native adaptation processes the sequence in chunks: the intra-chunk
token-vs-token decay matrix is materialized in VMEM (exponents <= 0 —
numerically safe), the cross-chunk state (hd x hd per head) rides in VMEM
scratch across the sequential chunk grid dimension, and all heavy ops are
MXU matmuls.

Layout: r,k,v,logw (BH, T, hd); u (BH, hd); state0 (BH, hd, hd).
Grid (BH, T/C): chunk index minor/sequential.

TPU sizing: hd = 64 (Finch), chunk C = 128: decay tensor (C, C, hd) f32 is
8 MB — inside VMEM; the scores/gemm ops are (C, hd)x(hd, C) and
(C, C)x(C, hd) matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
            s_ref, *, chunk):
    ic = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)                      # (C, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    logw = w_ref[0].astype(jnp.float32)                   # (C, hd), <= 0
    u = u_ref[0].astype(jnp.float32)                      # (hd,)
    s = s_ref[...]                                        # (hd, hd)
    C, hd = r.shape

    c = jnp.cumsum(logw, axis=0)                          # inclusive
    b = c - logw                                          # exclusive
    # intra-chunk decay D[t, s, :] = exp(b_t - c_s) for s < t ; u at s == t
    diff = b[:, None, :] - c[None, :, :]                  # (C, C, hd)
    tt = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    ss = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    lower = (tt > ss)[:, :, None]
    diag = (tt == ss)[:, :, None]
    D = jnp.where(lower, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    D = D + diag * u[None, None, :]
    score = ((r[:, None, :] * k[None, :, :]) * D).sum(-1)  # (C, C)
    y = jax.lax.dot_general(score, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: queries attend to the carried state
    y = y + jax.lax.dot_general(r * jnp.exp(b), s, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: S' = exp(c_C) * (S + k~^T v), k~_s = k_s exp(-c_s)
    # (stable form: exp(c_C - c_s) <= 1 applied per term)
    kd = k * jnp.exp(c[-1:, :] - c)                       # (C, hd)
    s_new = jnp.exp(c[-1])[:, None] * s + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s_ref[...] = s_new

    @pl.when(ic == nc - 1)
    def _finish():
        sout_ref[0] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_wkv(r, k, v, logw, u, state0, *, chunk: int = 128,
              interpret: bool = False):
    """Chunked WKV: returns (y (BH,T,hd), final state (BH,hd,hd))."""
    BH, T, hd = r.shape
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        logw = zp(logw)          # logw = 0 -> w = 1: padding is a no-op
    Tp = T + pad
    grid = (BH, Tp // chunk)
    y, sout = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hd), lambda b, c: (b, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Tp, hd), r.dtype),
            jax.ShapeDtypeStruct((BH, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, state0)
    return y[:, :T], sout
