"""Pallas TPU kernels for the perf-critical compute layers.

  score_ce.py        — fused Eqn-1 scoring CE (Prompt Bank hot spot)
  flash_attention.py — GQA flash attention (causal / sliding window / cache)
  flash_decode.py    — split-KV flash decode (single-token GQA inference)
  mla_decode.py      — absorbed MLA latent decode (DeepSeek-V2/Kimi-K2)
  rwkv_wkv.py        — RWKV6 chunked WKV scan (data-dependent decay)

Each kernel has a pure-jnp oracle in ref.py and model-layout wrappers in
ops.py; tests sweep shapes/dtypes against the oracles (interpret=True on
CPU, Mosaic on real TPUs).
"""
from repro.kernels.ops import (
    fused_score_ce,
    gqa_flash,
    gqa_flash_decode,
    mla_flash_decode,
    wkv,
)

__all__ = [
    "fused_score_ce",
    "gqa_flash",
    "gqa_flash_decode",
    "mla_flash_decode",
    "wkv",
]
