"""MLA latent decode kernel (DeepSeek-V2/V3 matrix-absorbed attention).

With the matrix-absorption trick (W_UK folded into the query, W_UV into
the output projection — pie's ``DsmaAttention`` convention) decode
attention runs entirely in the compressed latent space:

    scores = q_lat @ ckv^T + q_pe @ kpe^T        (nope + rope parts)
    out    = softmax(scores) @ ckv               (ckv doubles as V)

so the per-step HBM floor is ONE read of the latent cache
``(L, r + rd)`` — not the H-times-larger decompressed K/V. The score
matrix is the only O(H * L) object and it never leaves VMEM.

Layout (all H query heads share the single latent KV "head"):
  q_lat (B, H, r)   q_pe (B, H, rd)   ckv (B, L, r)   kpe (B, L, rd)
with r = kv_lora_rank (512 for deepseek-v2/kimi-k2) and
rd = qk_rope_head_dim (64). H itself forms the MXU rows (128 heads on
deepseek-v2 — a full systolic tile per score matmul).

Grid (B, splits, nk): split-KV exactly like ``flash_decode`` — each
partition keeps (m, l, acc) VMEM scratch across its ``nk`` KV tiles and
emits an l-normalized partial plus its LSE; partials merge with the
shared ``combine_partials`` rescale (exact).

Masking is dynamic (SMEM): column j live iff j < kv_len and j <= q_pos.
``scale`` is static: 1/sqrt(qk_nope_head_dim + qk_rope_head_dim) — the
*pre-absorption* head dim, NOT the latent rank.

TPU sizing: bk = 256 tiles: ckv tile (256, 512) f32 + kpe (256, 64)
+ scores (H', 256) + acc (H', 512) ~= 1.1 MB at H' = 128 — VMEM-light,
so wide splits keep every core busy on long caches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_decode import NEG_INF, combine_partials


def _kernel(meta_ref, ql_ref, qp_ref, ckv_ref, kpe_ref, o_ref, lse_ref,
            m_ref, l_ref, acc_ref, *, scale, bk):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)
    isplit = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ql = ql_ref[0].astype(jnp.float32)                    # (H', r)
    qp = qp_ref[0].astype(jnp.float32)                    # (H', rd)
    ckv = ckv_ref[0].astype(jnp.float32)                  # (bk, r)
    kpe = kpe_ref[0].astype(jnp.float32)                  # (bk, rd)
    s = (jax.lax.dot_general(ql, ckv, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
         + jax.lax.dot_general(qp, kpe, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)) * scale

    kv_len, q_pos = meta_ref[0], meta_ref[1]
    nh = s.shape[0]
    kpos = (isplit * nk + ik) * bk + jax.lax.broadcasted_iota(
        jnp.int32, (nh, bk), 1)
    ok = (kpos < kv_len) & (kpos <= q_pos)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(ok, p, 0.0)          # fully-masked tile: exp(0) guard
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, ckv, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[...]
        denom = jnp.maximum(l, 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)
        lse_ref[0, 0] = jnp.where(l > 0.0, m_ref[...] + jnp.log(denom[:, 0]),
                                  NEG_INF)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "splits", "bk", "interpret"),
)
def mla_decode(q_lat: jax.Array, q_pe: jax.Array, ckv: jax.Array,
               kpe: jax.Array, *, scale: float, kv_len=None, q_pos=None,
               splits: int = 8, bk: int = 256,
               interpret: bool = False) -> jax.Array:
    """q_lat: (B,H,r); q_pe: (B,H,rd); ckv: (B,L,r); kpe: (B,L,rd)
    -> (B,H,r) latent attention output (decompress with W_UV outside).

    ``kv_len`` / ``q_pos`` are dynamic scalars with the same contiguous-
    prefix convention as ``flash_decode``."""
    B, H, r = q_lat.shape
    rd = q_pe.shape[-1]
    L = ckv.shape[1]

    nh = max(8, -(-H // 8) * 8)                           # f32 sublane pad
    if nh != H:
        q_lat = jnp.pad(q_lat, ((0, 0), (0, nh - H), (0, 0)))
        q_pe = jnp.pad(q_pe, ((0, 0), (0, nh - H), (0, 0)))

    bk = min(bk, max(128, -(-L // 128) * 128))
    nsplit = min(splits, -(-L // bk))
    per = nsplit * bk
    Lp = -(-L // per) * per
    if Lp != L:
        ckv = jnp.pad(ckv, ((0, 0), (0, Lp - L), (0, 0)))
        kpe = jnp.pad(kpe, ((0, 0), (0, Lp - L), (0, 0)))
    nk = Lp // per

    if kv_len is None:
        kv_len = L
    kv_len = jnp.asarray(kv_len, jnp.int32)
    if q_pos is None:
        q_pos = kv_len - 1
    meta = jnp.stack([kv_len, jnp.asarray(q_pos, jnp.int32)])

    grid = (B, nsplit, nk)
    o_part, lse = pl.pallas_call(
        functools.partial(_kernel, scale=scale, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),        # meta (2,)
            pl.BlockSpec((1, nh, r), lambda b, s, j: (b, 0, 0)),
            pl.BlockSpec((1, nh, rd), lambda b, s, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, r),
                         lambda b, s, j, nk=nk: (b, s * nk + j, 0)),
            pl.BlockSpec((1, bk, rd),
                         lambda b, s, j, nk=nk: (b, s * nk + j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, nh, r), lambda b, s, j: (b, s, 0, 0)),
            pl.BlockSpec((1, 1, nh), lambda b, s, j: (b, s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nsplit, nh, r), jnp.float32),
            jax.ShapeDtypeStruct((B, nsplit, nh), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((nh,), jnp.float32),               # running max
            pltpu.VMEM((nh,), jnp.float32),               # running sum
            pltpu.VMEM((nh, r), jnp.float32),             # latent accumulator
        ],
        interpret=interpret,
    )(meta, q_lat, q_pe, ckv, kpe)
    out = combine_partials(o_part, lse, axis=1)           # (B, nh, r)
    return out[:, :H].astype(q_lat.dtype)
