"""Split-KV flash decode (GQA single-token inference attention).

The serving-side hot loop is one query token against a long KV cache.
A plain flash grid gives that token ONE grid cell per (batch, head) —
on a 128k cache that is a single sequential pass over HBM with no
parallelism across cores. Flash-decoding fixes this by partitioning the
KV cache across grid cells: every partition keeps its own online-softmax
state ``(m, l, acc)`` while streaming its KV tiles through VMEM, then
emits a *normalized partial output* plus its log-sum-exp. The partials
are merged with the standard LSE rescale/combine reduction
(AttentionEngine's ``combine``: ``o_scale = exp(lse_i - logsumexp_i
lse_i)``), which is exact — no approximation anywhere.

Layout (GQA group packed into MXU rows so S=1 still feeds a matmul):
  q (B, Hkv, G, hd)     k,v (B, Hkv, L, hd)      G = H // Hkv
Grid (B, Hkv, splits, nk): the inner KV-tile index is minor; VMEM
scratch carries (m, l, acc) across the ``nk`` tiles of one partition.

Masking is positional and dynamic (SMEM): KV column j is live iff
  j <  kv_len                 (valid cache prefix)
  j <= q_pos                  (causal; q_pos defaults to kv_len - 1)
  j >  q_pos - window         (sliding window, if window > 0)

Outputs per partition: o_part (B, Hkv, splits, G, hd) normalized by the
partition's own ``l``, and lse (B, Hkv, splits, G); empty partitions
(fully masked) emit lse = -inf so their combine weight is exactly 0.

TPU sizing: tiles default to bk = 256, G padded to a multiple of 8
(f32 sublane): live set k/v (256, hd) + scores (G', 256) + acc (G', hd)
~= 0.6 MB at hd = 128 bf16 — tiny, so ``splits`` can go wide and the
kernel stays HBM-bound at ~2*L*hd*Hkv bytes per (batch, kv-head), the
roofline floor for reading the cache once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(meta_ref, q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref,
            acc_ref, *, scale, window, bk):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)
    isplit = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                   # (G', hd)
    k = k_ref[0, 0].astype(jnp.float32)                   # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                             # (G', bk)

    kv_len, q_pos = meta_ref[0], meta_ref[1]
    gq = q.shape[0]
    kpos = (isplit * nk + ik) * bk + jax.lax.broadcasted_iota(
        jnp.int32, (gq, bk), 1)
    ok = (kpos < kv_len) & (kpos <= q_pos)
    if window and window > 0:
        ok &= kpos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(ok, p, 0.0)          # exp(NEG_INF - NEG_INF) = 1 guard
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[...]
        denom = jnp.maximum(l, 1e-30)[:, None]
        o_ref[0, 0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)
        lse_ref[0, 0, 0] = jnp.where(l > 0.0, m_ref[...] + jnp.log(denom[:, 0]),
                                     NEG_INF)


def combine_partials(o_part: jax.Array, lse: jax.Array,
                     axis: int = 2) -> jax.Array:
    """LSE rescale/combine across split-KV partitions (exact).

    o_part: (..., splits, ..., hd) partials each normalized by their own
    softmax sum; lse: matching shape without the trailing hd. Weights are
    ``exp(lse_i - max_i lse_i)`` renormalized — an all-empty row (every
    lse = -inf) combines to exactly 0.
    """
    m = lse.max(axis=axis, keepdims=True)
    w = jnp.exp(lse - jnp.maximum(m, NEG_INF))            # (..., splits, ...)
    w = jnp.where(lse > NEG_INF / 2, w, 0.0)
    denom = jnp.maximum(w.sum(axis=axis, keepdims=True), 1e-30)
    return ((o_part * w[..., None]).sum(axis=axis) /
            denom[..., None].squeeze(axis)).astype(o_part.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "splits", "bk", "interpret"),
)
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 kv_len=None, q_pos=None, window: int = 0,
                 splits: int = 8, bk: int = 256,
                 interpret: bool = False) -> jax.Array:
    """q: (B,H,hd); k,v: (B,Hkv,L,hd) -> (B,H,hd).

    ``kv_len``: dynamic valid-cache length (defaults to L); ``q_pos``:
    dynamic absolute position of the query token (defaults to
    ``kv_len - 1``, i.e. the token attends to the whole valid prefix
    including itself). Both are scalars shared across the batch, the
    contiguous-prefix convention of ``gqa_init_cache``.
    """
    B, H, hd = q.shape
    Hkv, L = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    G = H // Hkv

    # pack the GQA group into MXU rows, padded to the f32 sublane count
    gq = max(8, -(-G // 8) * 8)
    qg = q.reshape(B, Hkv, G, hd)
    if gq != G:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, gq - G), (0, 0)))

    bk = min(bk, max(128, -(-L // 128) * 128))
    nsplit = min(splits, -(-L // bk))
    per = nsplit * bk
    Lp = -(-L // per) * per
    if Lp != L:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Lp - L), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Lp - L), (0, 0)))
    nk = Lp // per

    if kv_len is None:
        kv_len = L
    kv_len = jnp.asarray(kv_len, jnp.int32)
    if q_pos is None:
        q_pos = kv_len - 1
    meta = jnp.stack([kv_len, jnp.asarray(q_pos, jnp.int32)])

    grid = (B, Hkv, nsplit, nk)
    o_part, lse = pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / (hd ** 0.5), window=window,
                          bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),        # meta (2,)
            pl.BlockSpec((1, 1, gq, hd), lambda b, h, s, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, s, j, nk=nk: (b, h, s * nk + j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, s, j, nk=nk: (b, h, s * nk + j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, gq, hd), lambda b, h, s, j: (b, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, gq), lambda b, h, s, j: (b, h, s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, nsplit, gq, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, nsplit, gq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((gq,), jnp.float32),               # running max
            pltpu.VMEM((gq,), jnp.float32),               # running sum
            pltpu.VMEM((gq, hd), jnp.float32),            # accumulator
        ],
        interpret=interpret,
    )(meta, qg, k, v)
    out = combine_partials(o_part, lse, axis=2)           # (B, Hkv, gq, hd)
    return out[:, :, :G].reshape(B, H, hd).astype(q.dtype)
