"""Fused prompt-score cross-entropy kernel (the Prompt Bank hot spot).

Eqn 1 evaluates ``score(p) = mean NLL of concat(p, d_in) -> d_tgt``: a
forward pass whose final ``hidden @ E^T -> log_softmax -> gather(gold)``
dominates time and memory at LLM vocab sizes (V up to 257k here). The
naive path materializes (T, V) logits in HBM; this kernel streams vocab
tiles through VMEM with an online logsumexp, so the logits never exist.

Layout:
  hidden (T, D)   - flattened (batch*seq) token hiddens
  emb    (V, D)   - (tied) unembedding matrix
  labels (T,)     - gold token ids
  out    nll (T,) - per-token negative log-likelihood, f32

Grid (nt, nv): vocab is the minor (fastest) dimension; VMEM scratch
carries the running max ``m``, running sum ``l`` and the gold logit
across vocab tiles; the final tile writes ``log(l) + m - gold``.

TPU sizing: tiles default to (bt, bv) = (256, 512); VMEM live set is
hidden tile (bt, D) + emb tile (bv, D) + logits tile (bt, bv), i.e.
~7.9 MB at D = 4096 in bf16 — under the ~16 MB v5e VMEM budget. MXU work
is the (bt, D) x (D, bv) matmul with all dims 128-aligned.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(h_ref, e_ref, lab_ref, nll_ref, m_ref, l_ref, gold_ref):
    iv = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(iv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        gold_ref[...] = jnp.zeros_like(gold_ref)

    h = h_ref[...].astype(jnp.float32)                    # (bt, D)
    e = e_ref[...].astype(jnp.float32)                    # (bv, D)
    logits = jax.lax.dot_general(
        h, e, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                     # (bt, bv)
    bt, bv = logits.shape

    # online logsumexp
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.exp(
        logits - m_new[:, None]
    ).sum(axis=-1)
    m_ref[...] = m_new

    # gold logit if it falls inside this vocab tile
    labels = lab_ref[...]                                 # (bt,) i32 global ids
    v0 = iv * bv
    local = labels - v0
    in_tile = (local >= 0) & (local < bv)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bt, bv), 1)
    hit = cols == jnp.where(in_tile, local, -1)[:, None]
    gold_ref[...] = gold_ref[...] + jnp.where(hit, logits, 0.0).sum(axis=-1)

    @pl.when(iv == nv - 1)
    def _finish():
        nll_ref[...] = (jnp.log(jnp.maximum(l_ref[...], 1e-30)) + m_ref[...]
                        - gold_ref[...])


@functools.partial(jax.jit, static_argnames=("bt", "bv", "interpret"))
def score_ce(hidden: jax.Array, emb: jax.Array, labels: jax.Array, *,
             bt: int = 256, bv: int = 512,
             interpret: bool = False) -> jax.Array:
    """Per-token NLL (T,) f32 of ``softmax(hidden @ emb.T)`` at ``labels``.

    Pads T and V up to tile multiples (padded vocab rows are -inf-free
    because emb padding contributes exp(logit)=exp(0·h)=1 — so V padding
    uses a -inf additive trick instead: padded vocab columns are masked by
    the hit/max math operating on real tiles only; we pad emb with zeros
    and subtract their contribution by masking in-kernel via tile bounds.
    For simplicity, V must be a multiple of bv and T is padded here.)
    """
    T, D = hidden.shape
    V = emb.shape[0]
    assert V % bv == 0, f"V={V} must divide bv={bv} (pad the vocab)"
    tpad = (-T) % bt
    if tpad:
        hidden = jnp.pad(hidden, ((0, tpad), (0, 0)))
        labels = jnp.pad(labels, ((0, tpad),))
    Tp = T + tpad
    grid = (Tp // bt, V // bv)
    nll = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, D), lambda it, iv: (it, 0)),
            pl.BlockSpec((bv, D), lambda it, iv: (iv, 0)),
            pl.BlockSpec((bt,), lambda it, iv: (it,)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda it, iv: (it,)),
        out_shape=jax.ShapeDtypeStruct((Tp,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bt,), jnp.float32),    # running max m
            pltpu.VMEM((bt,), jnp.float32),    # running sum l
            pltpu.VMEM((bt,), jnp.float32),    # gold logit
        ],
        interpret=interpret,
    )(hidden, emb, labels)
    return nll[:T]
