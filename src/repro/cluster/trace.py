"""LPT workload traces (paper §2.2 Fig 2b, §6.1 'Workload Construction').

The paper samples 20-minute traces from a production cluster with highly
spiky arrivals (max requests/min ~ 5x the mean). We reproduce that shape
with a two-state (base / spike) modulated Poisson process and attach to
each request:

  * an LLM (gpt2-base / gpt2-large / vicuna-7b, or the heavy models),
  * a duration drawn from a lognormal spanning "a few seconds to several
    minutes" (paper: job durations vary from seconds to minutes),
  * an SLO  = duration * S + allocation overhead (S = "SLO emergence"),
  * ITA values for the four initialization strategies (manual / induction
    / bank 'score' / ideal), derived from a relative-speedup distribution
    that can be CALIBRATED from real testbed measurements
    (`benchmarks/bench_bank.py` writes ``artifacts/ita_calibration.json``).

Loads follow §6.1: low (41/55/42), medium (77/71/65), high (99/85/76)
requests per LLM (GPT2-B / GPT2-L / V7B) in 20 minutes.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.jobs import (
    DEFAULT_SLO_CLASS,
    DEFAULT_TENANT,
    LLM_PROFILES,
    SLO_CLASSES,
    Job,
    SLOClass,
    iter_time,
)

TRACE_MINUTES = 20
LOADS: Dict[str, Dict[str, int]] = {
    "low": {"gpt2-base": 41, "gpt2-large": 55, "vicuna-7b": 42},
    "medium": {"gpt2-base": 77, "gpt2-large": 71, "vicuna-7b": 65},
    "high": {"gpt2-base": 99, "gpt2-large": 85, "vicuna-7b": 76},
}
HEAVY_LOADS: Dict[str, Dict[str, int]] = {
    "llama-30b": {"llama-30b": 59},
    "qwen7b-r1": {"qwen7b-r1": 70},
}

# Fallback ITA-speedup distributions (relative to the manual prompt's
# iteration count), used until bench_bank writes a measured calibration.
#   Fig 2c: median / max ITA are 1.7-4.5x the min -> manual is the typical
#   draw, ideal ~= the min.  Fig 9b: score vs induction speedup 1.2-4.7x.
DEFAULT_CALIBRATION = {
    # manual_over_ideal: how many times more iterations manual needs
    "manual_over_ideal": {"lo": 1.7, "hi": 4.5},
    # score (bank) ITA is >= 90 % of ideal for most tasks (Fig 9a)
    "bank_over_ideal": {"lo": 1.0, "hi": 1.25},
    # induction sits between manual and bank; worse for weak LLMs (Fig 9b)
    "induction_over_bank": {
        "gpt2-base": {"lo": 1.8, "hi": 2.8},
        "gpt2-large": {"lo": 1.38, "hi": 2.2},
        "vicuna-7b": {"lo": 1.28, "hi": 1.9},
        "llama-30b": {"lo": 1.25, "hi": 1.8},
        "qwen7b-r1": {"lo": 1.3, "hi": 1.9},
    },
}

CALIBRATION_PATH = os.path.join(
    os.environ.get("REPRO_ARTIFACTS", "artifacts"), "ita_calibration.json"
)


def load_calibration() -> Dict:
    if os.path.exists(CALIBRATION_PATH):
        with open(CALIBRATION_PATH) as f:
            measured = json.load(f)
        cal = json.loads(json.dumps(DEFAULT_CALIBRATION))  # deep copy
        cal.update(measured)
        return cal
    return DEFAULT_CALIBRATION


def _rng_range(rng: np.random.Generator, spec: Dict) -> float:
    return float(rng.uniform(spec["lo"], spec["hi"]))


@dataclass
class TraceConfig:
    load: str = "medium"              # low | medium | high, or heavy model name
    slo_emergence: float = 1.0        # S (paper Fig 7c/d: 0.5 / 1.0 / 1.5)
    minutes: int = TRACE_MINUTES
    seed: int = 0
    spike_prob: float = 0.12          # fraction of spike minutes
    spike_mult: float = 5.0           # paper: max rpm ~ 5x mean
    duration_lo: float = 5.0          # seconds
    duration_hi: float = 300.0
    scale: float = 1.0                # multiply request counts (scalability eval)
    llms: Optional[Sequence[str]] = None
    tenant: str = DEFAULT_TENANT      # stamp every job with this tenant
    slo_class: SLOClass = DEFAULT_SLO_CLASS  # ... and this service class


def arrival_times(
    rng: np.random.Generator, total: int, minutes: int, spike_prob: float,
    spike_mult: float,
) -> np.ndarray:
    """Two-state modulated Poisson: spike minutes carry spike_mult x base
    intensity; overall count is ~total."""
    weights = np.where(rng.random(minutes) < spike_prob, spike_mult, 1.0)
    per_min = rng.multinomial(total, weights / weights.sum())
    times = []
    for m, n in enumerate(per_min):
        times.extend(60.0 * m + rng.random(n) * 60.0)
    return np.sort(np.asarray(times))


def generate_trace(cfg: TraceConfig) -> List[Job]:
    """Returns Jobs sorted by submit time with per-strategy ITA attached."""
    rng = np.random.default_rng(cfg.seed)
    cal = load_calibration()
    if cfg.load in LOADS:
        counts = dict(LOADS[cfg.load])
    elif cfg.load in HEAVY_LOADS:
        counts = dict(HEAVY_LOADS[cfg.load])
    else:
        raise KeyError(f"unknown load {cfg.load!r}")
    if cfg.llms is not None:
        counts = {k: v for k, v in counts.items() if k in cfg.llms}
    jobs: List[Job] = []
    jid = 0
    for llm, n in counts.items():
        n = max(int(round(n * cfg.scale)), 1)
        prof = LLM_PROFILES[llm]
        times = arrival_times(rng, n, cfg.minutes, cfg.spike_prob, cfg.spike_mult)
        for t in times:
            # `dur` is the duration observed in the PRODUCTION trace —
            # i.e. with the production system's (bank-quality) initial
            # prompt on one replica. Manual/induction inits need 1.3-4.5x
            # more iterations (Fig 2c / Fig 9), which is what makes SLOs
            # tight for systems without prompt reusing.
            mu = np.log(np.sqrt(cfg.duration_lo * cfg.duration_hi))
            sigma = np.log(cfg.duration_hi / cfg.duration_lo) / 4.0
            dur = float(np.clip(rng.lognormal(mu, sigma),
                                cfg.duration_lo, cfg.duration_hi))
            it1 = iter_time(prof, prof.gpus_per_replica)
            iters_bank = max(int(dur / it1), 2)
            b_over_i = _rng_range(rng, cal["bank_over_ideal"])
            iters_ideal = max(int(iters_bank / b_over_i), 2)
            m_over_i = _rng_range(rng, cal["manual_over_ideal"])
            iters_manual = max(int(iters_ideal * m_over_i), 4)
            ind_spec = cal["induction_over_bank"].get(
                llm, {"lo": 1.3, "hi": 2.0})
            iters_induction = max(int(iters_bank * _rng_range(rng, ind_spec)), 2)
            # SLO = trace duration x S + one allocation overhead (§6.1),
            # scaled by the service class's stringency (standard = 1.0)
            slo = (dur * cfg.slo_emergence + prof.cold_overhead) \
                * cfg.slo_class.slo_multiplier
            job = Job(
                job_id=jid,
                llm=llm,
                submit_time=float(t),
                slo=float(slo),
                iters_manual=iters_manual,
                iters_bank=iters_bank,
                task_id=f"task{jid % 120}",
                tenant=cfg.tenant,
                slo_class=cfg.slo_class,
            )
            job.iters_ideal = iters_ideal            # extra attrs for ablations
            job.iters_induction = iters_induction
            jobs.append(job)
            jid += 1
    jobs.sort(key=lambda j: j.submit_time)
    for i, j in enumerate(jobs):
        j.job_id = i
    return jobs


@dataclass
class TenantSpec:
    """One tenant's slice of a multi-tenant trace: its load/SLO profile
    plus the service class it bought. ``slo_class`` accepts a catalogue
    name (``premium`` / ``standard`` / ``best-effort``) or an ad-hoc
    :class:`~repro.core.jobs.SLOClass`."""

    name: str
    load: str = "medium"              # low | medium | high, or heavy model
    slo_class: Union[str, SLOClass] = "standard"  # SLO_CLASSES key or ad-hoc
    scale: float = 1.0                # per-tenant load multiplier
    slo_emergence: float = 1.0        # per-tenant S (SLO stringency)
    spike_prob: float = 0.12          # per-tenant burst shape: fraction of
    spike_mult: float = 5.0           # spike minutes and their intensity

    def resolved_class(self) -> SLOClass:
        if isinstance(self.slo_class, SLOClass):
            return self.slo_class
        return SLO_CLASSES[self.slo_class]


DEFAULT_TENANT_MIX = (
    TenantSpec("acme", load="medium", slo_class="premium", scale=0.5),
    TenantSpec("globex", load="medium", slo_class="standard"),
    TenantSpec("initech", load="high", slo_class="best-effort", scale=0.7),
)

# The elastic-control-plane stressor: heavier aggregate load than the
# default mix, much spikier arrivals (most of a tenant's traffic lands
# in a few burst minutes), and imbalanced per-tenant scales. Static
# placement strands these bursts on whichever shards the placement
# hashed them to; work stealing and queue-pressure autoscaling are
# exactly the mechanisms that win here (`bench_multitenant` measures
# the head-to-head).
BURSTY_TENANT_MIX = (
    TenantSpec("acme", load="high", slo_class="premium", scale=0.6,
               spike_prob=0.25, spike_mult=8.0),
    TenantSpec("globex", load="medium", slo_class="standard",
               spike_prob=0.15, spike_mult=10.0),
    TenantSpec("initech", load="high", slo_class="best-effort", scale=1.2,
               spike_prob=0.3, spike_mult=6.0),
)


def generate_tenant_mix(
    tenants: Sequence[TenantSpec] = DEFAULT_TENANT_MIX,
    *,
    minutes: int = TRACE_MINUTES,
    seed: int = 0,
) -> List[Job]:
    """A multi-tenant workload: each tenant's sub-trace is generated with
    its own load / scale / stringency (decorrelated seeds), stamped with
    the tenant's identity and service class, and the union is merged in
    arrival order with globally unique job ids."""
    jobs: List[Job] = []
    for k, spec in enumerate(tenants):
        cls = spec.resolved_class()
        sub = generate_trace(TraceConfig(
            load=spec.load, slo_emergence=spec.slo_emergence,
            minutes=minutes, seed=seed + 7919 * (k + 1), scale=spec.scale,
            spike_prob=spec.spike_prob, spike_mult=spec.spike_mult,
            tenant=spec.name, slo_class=cls,
        ))
        for j in sub:
            j.task_id = f"{spec.name}/{j.task_id}"
        jobs.extend(sub)
    jobs.sort(key=lambda j: (j.submit_time, j.tenant))
    for i, j in enumerate(jobs):
        j.job_id = i
    return jobs


def clone_jobs(jobs: List[Job]) -> List[Job]:
    """Fresh Job copies (runtime state reset) so the same trace can be
    replayed through several systems."""
    out = []
    for j in jobs:
        c = Job(job_id=j.job_id, llm=j.llm, submit_time=j.submit_time,
                slo=j.slo, iters_manual=j.iters_manual,
                iters_bank=j.iters_bank, max_iters=j.max_iters,
                task_id=j.task_id, tenant=j.tenant, slo_class=j.slo_class)
        for extra in ("iters_ideal", "iters_induction"):
            if hasattr(j, extra):
                setattr(c, extra, getattr(j, extra))
        out.append(c)
    return out
