from repro.cluster.sim import ClusterSim, SimConfig, SimResult, JobRecord, WarmPool
from repro.cluster.trace import (
    clone_jobs,
    LOADS,
    HEAVY_LOADS,
    TraceConfig,
    generate_trace,
    load_calibration,
)
from repro.cluster.baselines import ElasticFlowSim, INFlessSim, make_system

__all__ = [
    "ClusterSim",
    "ElasticFlowSim",
    "HEAVY_LOADS",
    "INFlessSim",
    "JobRecord",
    "LOADS",
    "SimConfig",
    "SimResult",
    "TraceConfig",
    "WarmPool",
    "clone_jobs",
    "generate_trace",
    "load_calibration",
    "make_system",
]
