from repro.cluster.engine import (
    ClusterEngine,
    ClusterSim,
    EngineEvent,
    JobRecord,
    ResourceView,
    SHARED_POOL,
    SimConfig,
    SimResult,
    WarmPool,
)
from repro.cluster import policies
from repro.cluster.policies import SchedulingPolicy
from repro.cluster.fabric import ClusterFabric, placements, register_placement
from repro.cluster.trace import (
    clone_jobs,
    DEFAULT_TENANT_MIX,
    LOADS,
    HEAVY_LOADS,
    TenantSpec,
    TraceConfig,
    generate_tenant_mix,
    generate_trace,
    load_calibration,
)
from repro.cluster.baselines import ElasticFlowSim, INFlessSim, make_system

__all__ = [
    "ClusterEngine",
    "ClusterFabric",
    "ClusterSim",
    "DEFAULT_TENANT_MIX",
    "ElasticFlowSim",
    "EngineEvent",
    "HEAVY_LOADS",
    "INFlessSim",
    "JobRecord",
    "LOADS",
    "ResourceView",
    "SHARED_POOL",
    "SchedulingPolicy",
    "SimConfig",
    "SimResult",
    "TenantSpec",
    "TraceConfig",
    "WarmPool",
    "clone_jobs",
    "generate_tenant_mix",
    "generate_trace",
    "load_calibration",
    "make_system",
    "placements",
    "policies",
    "register_placement",
]
