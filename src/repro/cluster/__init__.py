from repro.cluster.engine import (
    ClusterEngine,
    ClusterSim,
    JobRecord,
    ResourceView,
    SimConfig,
    SimResult,
    WarmPool,
)
from repro.cluster import policies
from repro.cluster.policies import SchedulingPolicy
from repro.cluster.trace import (
    clone_jobs,
    LOADS,
    HEAVY_LOADS,
    TraceConfig,
    generate_trace,
    load_calibration,
)
from repro.cluster.baselines import ElasticFlowSim, INFlessSim, make_system

__all__ = [
    "ClusterEngine",
    "ClusterSim",
    "ElasticFlowSim",
    "HEAVY_LOADS",
    "INFlessSim",
    "JobRecord",
    "LOADS",
    "ResourceView",
    "SchedulingPolicy",
    "SimConfig",
    "SimResult",
    "TraceConfig",
    "WarmPool",
    "clone_jobs",
    "generate_trace",
    "load_calibration",
    "make_system",
    "policies",
]
