"""Multi-tenant sharded cluster fabric.

A :class:`ClusterFabric` composes N independent :class:`ClusterEngine`
shards behind one submit/run surface — the ROADMAP's "sharded /
multi-cluster engines behind the same ``ResourceView``". Each shard is
a full engine with its own slice of the GPU fleet, its own warm/cold
pools, and its own policy instance, so every registered
:class:`~repro.cluster.policies.SchedulingPolicy` runs unmodified per
shard.

Placement (which shard a submitted job lands on) is a pluggable layer
with its own string-keyed registry:

* ``llm-affinity`` (default) — jobs of the same LLM share a shard, so
  warm runtimes consolidate instead of fragmenting across the fleet;
* ``least-loaded`` — the shard with the least outstanding work at
  submit time (pending queue depth + committed running GPUs);
* ``hash`` — uniform stable hash of (tenant, job id): tenant-striped,
  placement-oblivious.

Execution interleaves the shards' event loops in **global simulated-time
order** (the shard with the earliest next event steps first), so an
``on_event`` subscriber observes one time-ordered stream across the
whole fabric, each event stamped with its shard index.

Golden equivalence: ``ClusterFabric(cfg, shards=1)`` is exactly one
``ClusterEngine(cfg)`` — same events, same float-for-float summaries —
which is what pins this layer to the pre-fabric behaviour in
``tests/test_fabric.py``.
"""
from __future__ import annotations

import zlib
from dataclasses import replace
from typing import Callable, Dict, List, Optional, Sequence

from repro.cluster.engine import (
    ClusterEngine,
    EngineEvent,
    SimConfig,
    SimResult,
)
from repro.core.jobs import Job

PlacementFn = Callable[[Job, Sequence[ClusterEngine]], int]

_PLACEMENTS: Dict[str, PlacementFn] = {}


def register_placement(name: str):
    """Decorator: add a ``(job, shards) -> shard_index`` strategy to the
    placement registry under ``name``."""

    def deco(fn: PlacementFn) -> PlacementFn:
        _PLACEMENTS[name] = fn
        return fn

    return deco


def placements() -> List[str]:
    return sorted(_PLACEMENTS)


def _stable_hash(s: str) -> int:
    # zlib.crc32 (not hash()): str hashing is salted per process, and
    # placement must be reproducible across runs.
    return zlib.crc32(s.encode("utf-8"))


@register_placement("llm-affinity")
def place_llm_affinity(job: Job, shards: Sequence[ClusterEngine]) -> int:
    """All jobs of one LLM land on one shard: warm pools consolidate,
    runtime reuse stays as effective as on a monolithic cluster."""
    return _stable_hash(job.llm) % len(shards)


@register_placement("least-loaded")
def place_least_loaded(job: Job, shards: Sequence[ClusterEngine]) -> int:
    """The shard with the least outstanding work at submit time:
    jobs submitted but not yet finished (queued arrivals included —
    placement happens before the event loop runs), normalized per
    shard GPU."""
    def load(e: ClusterEngine) -> float:
        return e.outstanding_jobs / max(e.cfg.max_gpus, 1)

    return min(range(len(shards)), key=lambda i: (load(shards[i]), i))


@register_placement("hash")
def place_hash(job: Job, shards: Sequence[ClusterEngine]) -> int:
    """Uniform stable hash of (tenant, job id)."""
    return _stable_hash(f"{job.tenant}/{job.job_id}") % len(shards)


def _merge_results(per_shard: List[SimResult]) -> SimResult:
    if len(per_shard) == 1:
        return per_shard[0]
    records = [r for res in per_shard for r in res.records]
    records.sort(key=lambda r: (r.job.submit_time, r.job.job_id))
    util: List = sorted(
        (s for res in per_shard for s in res.util_samples),
        key=lambda s: s[0])
    cost_by_tenant: Dict[str, float] = {}
    gpu_s_by_tenant: Dict[str, float] = {}
    for res in per_shard:
        for t, v in res.cost_by_tenant.items():
            cost_by_tenant[t] = cost_by_tenant.get(t, 0.0) + v
        for t, v in res.gpu_seconds_by_tenant.items():
            gpu_s_by_tenant[t] = gpu_s_by_tenant.get(t, 0.0) + v
    return SimResult(
        records=records,
        cost=sum(res.cost for res in per_shard),
        gpu_seconds=sum(res.gpu_seconds for res in per_shard),
        makespan=max(res.makespan for res in per_shard),
        util_samples=util,
        cost_by_tenant=cost_by_tenant,
        gpu_seconds_by_tenant=gpu_s_by_tenant,
    )


class ClusterFabric:
    """N engine shards behind one submit/run/stream surface.

    ``cfg.max_gpus`` is the fleet total; it is split as evenly as
    possible across shards (earlier shards absorb the remainder). With
    ``shards=1`` the fabric is a transparent wrapper over a single
    engine and reproduces its results exactly.
    """

    def __init__(
        self,
        cfg: Optional[SimConfig] = None,
        policy: str = "prompttuner",
        *,
        shards: int = 1,
        placement: str = "llm-affinity",
    ):
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        cfg = cfg or SimConfig()
        if cfg.max_gpus < shards:
            raise ValueError(
                f"cannot split {cfg.max_gpus} GPUs across {shards} shards")
        if placement not in _PLACEMENTS:
            raise KeyError(
                f"unknown placement {placement!r}; available: {placements()}")
        from repro.cluster.policies import get as get_policy

        self.cfg = cfg
        self.policy_name = policy
        self.placement_name = placement
        self._place = _PLACEMENTS[placement]
        base, rem = divmod(cfg.max_gpus, shards)
        self.shards: List[ClusterEngine] = []
        for i in range(shards):
            shard_cfg = (cfg if shards == 1 else
                         replace(cfg, max_gpus=base + (1 if i < rem else 0)))
            self.shards.append(
                ClusterEngine(shard_cfg, get_policy(policy)(shard_cfg)))
        self.placed: Dict[int, int] = {}      # job_id -> shard index

    # -- streaming -----------------------------------------------------------

    def on_event(self, cb: Callable[[EngineEvent], None]) -> None:
        """Subscribe to the fabric-wide event stream (globally time-
        ordered; each event's ``shard`` is the originating shard)."""
        for i, eng in enumerate(self.shards):
            eng.on_event(
                lambda ev, _i=i: cb(replace(ev, shard=_i)))

    # -- submit / run --------------------------------------------------------

    def submit(self, job: Job) -> int:
        """Place ``job`` on a shard and enqueue its arrival; returns the
        shard index. Placement only considers shards large enough for
        the job's replica unit — an uneven GPU split must not strand a
        fleet-feasible job on a too-small shard. If no shard can ever
        hold one replica the job is genuinely unschedulable and any
        shard may record the violation."""
        need = job.profile().gpus_per_replica
        eligible = [i for i, e in enumerate(self.shards)
                    if e.cfg.max_gpus >= need]
        if eligible and len(eligible) < len(self.shards):
            sub = [self.shards[i] for i in eligible]
            i = eligible[self._place(job, sub)]
        else:
            i = self._place(job, self.shards)
        self.placed[job.job_id] = i
        self.shards[i].submit(job)
        return i

    def run(self, jobs: Sequence[Job] = ()) -> SimResult:
        """Drive every shard until no work is outstanding, interleaving
        shard event loops in global time order, and return the merged
        fleet-wide :class:`SimResult`. Like ``ClusterEngine.run`` this
        may be called repeatedly; state accumulates."""
        for j in jobs:
            self.submit(j)
        for eng in self.shards:
            eng.begin()
        while True:
            live = [(eng.next_event_time(), i)
                    for i, eng in enumerate(self.shards) if eng.has_events()]
            if not live:
                break
            _, i = min(live)
            self.shards[i].step()
        return _merge_results([eng.finish() for eng in self.shards])

    # -- introspection -------------------------------------------------------

    @property
    def now(self) -> float:
        """The fabric clock: the furthest-advanced shard."""
        return max(eng.now for eng in self.shards)

    @property
    def records(self):
        return [r for eng in self.shards for r in eng.records]

    def result(self) -> SimResult:
        """Merged fleet-wide result so far (no draining side effects)."""
        return _merge_results([eng.result() for eng in self.shards])

    def summary(self) -> Dict[str, float]:
        return self.result().summary()

    def summary_by_tenant(self) -> Dict[str, Dict[str, float]]:
        return self.result().summary_by_tenant()
