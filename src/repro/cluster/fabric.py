"""Multi-tenant sharded cluster fabric.

A :class:`ClusterFabric` composes N independent :class:`ClusterEngine`
shards behind one submit/run surface — the ROADMAP's "sharded /
multi-cluster engines behind the same ``ResourceView``". Each shard is
a full engine with its own slice of the GPU fleet, its own warm/cold
pools, and its own policy instance, so every registered
:class:`~repro.cluster.policies.SchedulingPolicy` runs unmodified per
shard.

Placement (which shard a submitted job lands on) is a pluggable layer
with its own string-keyed registry:

* ``llm-affinity`` (default) — jobs of the same LLM share a shard, so
  warm runtimes consolidate instead of fragmenting across the fleet;
* ``least-loaded`` — the shard with the least outstanding work at
  submit time (pending queue depth + committed running GPUs);
* ``hash`` — uniform stable hash of (tenant, job id): tenant-striped,
  placement-oblivious.

Execution interleaves the shards' event loops in **global simulated-time
order** (the shard with the earliest next event steps first), so an
``on_event`` subscriber observes one time-ordered stream across the
whole fabric, each event stamped with its shard index.

Golden equivalence: ``ClusterFabric(cfg, shards=1)`` is exactly one
``ClusterEngine(cfg)`` — same events, same float-for-float summaries —
which is what pins this layer to the pre-fabric behaviour in
``tests/test_fabric.py``.
"""
from __future__ import annotations

import zlib
from dataclasses import replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.cluster.elastic import (
    JOB_REJECTED,
    JOB_STOLEN,
    SHARD_RESIZED,
    ElasticConfig,
    ElasticController,
)
from repro.cluster.engine import (
    ClusterEngine,
    EngineEvent,
    JobRecord,
    SimConfig,
    SimResult,
)
from repro.cluster.faults import (
    FaultPlane,
    JOB_ORPHANED,
    JOB_RETRIED,
    JOB_SHED,
    SHARD_FAILED,
    SHARD_RECOVERED,
    SHARD_SLOWED,
    SHARD_WARNED,
)
from repro.cluster.health import fleet_health
from repro.core.jobs import Job, JobPhase

PlacementFn = Callable[[Job, Sequence[ClusterEngine]], int]

_PLACEMENTS: Dict[str, PlacementFn] = {}


def register_placement(name: str):
    """Decorator: add a ``(job, shards) -> shard_index`` strategy to the
    placement registry under ``name``."""

    def deco(fn: PlacementFn) -> PlacementFn:
        _PLACEMENTS[name] = fn
        return fn

    return deco


def placements() -> List[str]:
    return sorted(_PLACEMENTS)


def _stable_hash(s: str) -> int:
    # zlib.crc32 (not hash()): str hashing is salted per process, and
    # placement must be reproducible across runs.
    return zlib.crc32(s.encode("utf-8"))


@register_placement("llm-affinity")
def place_llm_affinity(job: Job, shards: Sequence[ClusterEngine]) -> int:
    """All jobs of one LLM land on one shard: warm pools consolidate,
    runtime reuse stays as effective as on a monolithic cluster."""
    return _stable_hash(job.llm) % len(shards)


@register_placement("least-loaded")
def place_least_loaded(job: Job, shards: Sequence[ClusterEngine]) -> int:
    """The shard with the least outstanding work at submit time:
    jobs submitted but not yet finished (queued arrivals included —
    placement happens before the event loop runs), normalized per
    shard GPU."""
    def load(e: ClusterEngine) -> float:
        return e.outstanding_jobs / max(e.cfg.max_gpus, 1)

    return min(range(len(shards)), key=lambda i: (load(shards[i]), i))


@register_placement("hash")
def place_hash(job: Job, shards: Sequence[ClusterEngine]) -> int:
    """Uniform stable hash of (tenant, job id)."""
    return _stable_hash(f"{job.tenant}/{job.job_id}") % len(shards)


def _merge_results(per_shard: List[SimResult]) -> SimResult:
    if len(per_shard) == 1:
        return per_shard[0]
    records = [r for res in per_shard for r in res.records]
    records.sort(key=lambda r: (r.job.submit_time, r.job.job_id))
    util: List = sorted(
        (s for res in per_shard for s in res.util_samples),
        key=lambda s: s[0])
    cost_by_tenant: Dict[str, float] = {}
    gpu_s_by_tenant: Dict[str, float] = {}
    for res in per_shard:
        for t, v in res.cost_by_tenant.items():
            cost_by_tenant[t] = cost_by_tenant.get(t, 0.0) + v
        for t, v in res.gpu_seconds_by_tenant.items():
            gpu_s_by_tenant[t] = gpu_s_by_tenant.get(t, 0.0) + v
    return SimResult(
        records=records,
        cost=sum(res.cost for res in per_shard),
        gpu_seconds=sum(res.gpu_seconds for res in per_shard),
        makespan=max(res.makespan for res in per_shard),
        util_samples=util,
        cost_by_tenant=cost_by_tenant,
        gpu_seconds_by_tenant=gpu_s_by_tenant,
    )


class ClusterFabric:
    """N engine shards behind one submit/run/stream surface.

    ``cfg.max_gpus`` is the fleet total; it is split as evenly as
    possible across shards (earlier shards absorb the remainder). With
    ``shards=1`` the fabric is a transparent wrapper over a single
    engine and reproduces its results exactly.
    """

    def __init__(
        self,
        cfg: Optional[SimConfig] = None,
        policy: str = "prompttuner",
        *,
        shards: int = 1,
        placement: str = "llm-affinity",
        elastic: Optional[Union[ElasticConfig, bool]] = None,
        faults: Optional[FaultPlane] = None,
    ):
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        cfg = cfg or SimConfig()
        if cfg.max_gpus < shards:
            raise ValueError(
                f"cannot split {cfg.max_gpus} GPUs across {shards} shards")
        if placement not in _PLACEMENTS:
            raise KeyError(
                f"unknown placement {placement!r}; available: {placements()}")
        from repro.cluster.policies import get as get_policy

        self.cfg = cfg
        self.policy_name = policy
        self.placement_name = placement
        self._place = _PLACEMENTS[placement]
        base, rem = divmod(cfg.max_gpus, shards)
        self.shards: List[ClusterEngine] = []
        self._subscribers: List[Callable[[EngineEvent], None]] = []
        for i in range(shards):
            shard_cfg = (cfg if shards == 1 else
                         replace(cfg, max_gpus=base + (1 if i < rem else 0)))
            self.shards.append(
                ClusterEngine(shard_cfg, get_policy(policy)(shard_cfg)))
            self._wire_shard(i)
        self.placed: Dict[int, int] = {}      # job_id -> shard index
        self.rejections: List[Tuple[Job, str]] = []   # quota-bounced jobs
        self._shed_records: List[JobRecord] = []      # fault-plane sheds
        self.controller: Optional[ElasticController] = None
        if elastic:
            self.controller = ElasticController(
                self, elastic if isinstance(elastic, ElasticConfig) else None)
        self.faults: Optional[FaultPlane] = None
        if faults is not None:
            self.faults = faults.attach(self)

    # -- streaming -----------------------------------------------------------

    def _wire_shard(self, i: int) -> None:
        """Register the one-and-only forwarding callback on shard ``i``.
        Called exactly once per shard, at shard creation: user
        subscriptions go through the fabric's own subscriber list, so
        subscribing at any time — and calling :meth:`run` repeatedly —
        never re-registers anything with an engine."""
        self.shards[i].on_event(
            lambda ev, _i=i: self._dispatch(replace(ev, shard=_i)))

    def _dispatch(self, ev: EngineEvent) -> None:
        for cb in list(self._subscribers):
            cb(ev)

    def announce(self, ev: EngineEvent) -> None:
        """Inject an externally-built event into the fabric-wide stream
        (delivered to every subscriber, exactly like an engine event).
        This is how stream-derived evaluators — the obs-layer
        ``AlertRules`` — publish typed ``alert_fired`` /
        ``alert_resolved`` events back onto the same bus the controller
        and telemetry already watch. ``_dispatch`` iterates a snapshot
        of the subscriber list, so announcing from inside a subscriber
        callback is safe."""
        self._dispatch(ev)

    def on_event(self, cb: Callable[[EngineEvent], None]) -> None:
        """Subscribe to the fabric-wide event stream (globally time-
        ordered; each event's ``shard`` is the originating shard).

        Subscribing any time after construction — before or between
        :meth:`run` calls — is the contract: delivery starts with the
        next processed event, each event is delivered exactly once per
        subscriber, and repeated ``run()`` calls never duplicate
        registrations. Besides the engine kinds (ARRIVAL / ROUND /
        JOB_DONE), an elastic fabric also emits ``job_stolen`` /
        ``shard_resized`` / ``job_rejected`` control-plane events."""
        self._subscribers.append(cb)

    # -- submit / run --------------------------------------------------------

    def submit(self, job: Job) -> int:
        """Place ``job`` on a shard and enqueue its arrival; returns the
        shard index, or ``-1`` if a tenant quota rejected the
        submission (recorded in :attr:`rejections` and emitted as a
        typed ``job_rejected`` event — the job is never placed and
        never billed). Placement only considers shards large enough for
        the job's replica unit — an uneven GPU split must not strand a
        fleet-feasible job on a too-small shard. If no shard can ever
        hold one replica the job is genuinely unschedulable and any
        shard may record the violation."""
        if self.controller is not None:
            reason = self.controller.admission_error(job)
            if reason is not None:
                self.rejections.append((job, reason))
                self.controller.rejections += 1
                if self.controller.audit is not None:
                    self.controller.audit.decision(
                        time=self.now, action=JOB_REJECTED, shard=-1,
                        job_id=job.job_id, tenant=job.tenant, detail=reason,
                        inputs={f"shard{h.shard}": h
                                for h in fleet_health(self.shards,
                                                      self.faults)})
                self._dispatch(EngineEvent(
                    kind=JOB_REJECTED, time=self.now, job=job, shard=-1,
                    detail=reason))
                return -1
        need = job.profile().gpus_per_replica
        eligible = [i for i, e in enumerate(self.shards)
                    if e.cfg.max_gpus >= need]
        if self.faults is not None:
            # avoid dead / preemption-warned / quarantined shards while
            # any healthy one remains (with none left, fall through to
            # the capacity-only list: queueing somewhere beats nowhere)
            healthy = [i for i in eligible if self.shard_admissible(i)]
            if healthy:
                eligible = healthy
        if eligible and len(eligible) < len(self.shards):
            sub = [self.shards[i] for i in eligible]
            k = self._place(job, sub)
            if not 0 <= k < len(sub):
                raise ValueError(
                    f"placement {self.placement_name!r} returned shard "
                    f"index {k} for job {job.job_id}, valid range is "
                    f"0..{len(sub) - 1}")
            i = eligible[k]
        else:
            i = self._place(job, self.shards)
            if not 0 <= i < len(self.shards):
                raise ValueError(
                    f"placement {self.placement_name!r} returned shard "
                    f"index {i} for job {job.job_id}, valid range is "
                    f"0..{len(self.shards) - 1}")
        self.placed[job.job_id] = i
        self.shards[i].submit(job)
        return i

    def run(self, jobs: Sequence[Job] = ()) -> SimResult:
        """Drive every shard until no work is outstanding, interleaving
        shard event loops in global time order, and return the merged
        fleet-wide :class:`SimResult`. Like ``ClusterEngine.run`` this
        may be called repeatedly; state accumulates."""
        for j in jobs:
            self.submit(j)
        for eng in self.shards:
            eng.begin()
        while True:
            live = [(eng.next_event_time(), i)
                    for i, eng in enumerate(self.shards) if eng.has_events()]
            ft = self.faults.next_time() if self.faults is not None else None
            if not live and ft is None:
                break
            if ft is not None and (not live or ft <= min(live)[0]):
                # fault-plane actions (injections, recoveries, retry
                # backoffs) fire at their exact simulated time, even
                # when every engine is idle
                self.faults.fire_next()
                continue
            _, i = min(live)
            self.shards[i].step()
        return self._final_result([eng.finish() for eng in self.shards])

    def _final_result(self, per_shard: List[SimResult]) -> SimResult:
        """Merge shard results plus any fault-plane shed records (each a
        terminal, violated outcome billed to no shard)."""
        if self._shed_records:
            per_shard = per_shard + [SimResult(
                records=list(self._shed_records), cost=0.0,
                gpu_seconds=0.0, makespan=0.0)]
        return _merge_results(per_shard)

    # -- elastic control-plane verbs -----------------------------------------

    def migrate(self, job_id: int, dst: int, *, at: Optional[float] = None
                ) -> bool:
        """Steal a still-pending job from its current shard onto ``dst``
        (placement-aware requeue): extracted from the donor's pending
        queue, re-admitted on ``dst`` with an arrival at the steal time,
        re-arming ``dst``'s round chain if it had drained. Returns False
        — with no state changed — if the job is not currently pending
        (already running/done) or ``dst`` cannot hold one replica.
        Emits a ``job_stolen`` event stamped with the receiving shard."""
        src = self.placed.get(job_id)
        if src is None or src == dst or not (0 <= dst < len(self.shards)):
            return False
        job_probe = None
        for j in self.shards[src].pending_jobs():
            if j.job_id == job_id:
                job_probe = j
                break
        if (job_probe is not None and
                job_probe.profile().gpus_per_replica
                > self.shards[dst].cfg.max_gpus):
            return False
        job = self.shards[src].extract_pending(job_id)
        if job is None:
            return False
        t = self.now if at is None else at
        self.placed[job_id] = dst
        self.shards[dst].admit_at(job, t)
        self._dispatch(EngineEvent(
            kind=JOB_STOLEN, time=t, job=job, shard=dst,
            detail=f"shard {src} -> {dst}"))
        return True

    def resize_shard(self, i: int, new_max_gpus: int, *,
                     at: Optional[float] = None) -> int:
        """Grow/shrink shard ``i``'s GPU slice (autoscaling hook).
        Shrinks only take free cold GPUs — warm pools, running jobs, and
        ledgers are untouched — so the returned actual capacity may be
        larger than requested. Emits a ``shard_resized`` event when the
        capacity changed. The fleet total is the caller's to conserve.
        A negative target raises ``ValueError`` (engine contract)."""
        eng = self.shards[i]
        before = eng.cfg.max_gpus
        after = eng.resize(new_max_gpus)
        if after != before:
            self._dispatch(EngineEvent(
                kind=SHARD_RESIZED, time=self.now if at is None else at,
                shard=i, detail=f"{before} -> {after} GPUs"))
        return after

    # -- fault-plane verbs (driven by repro.cluster.faults.FaultPlane) --------

    def shard_admissible(self, i: int) -> bool:
        """May new/retried work be placed on shard ``i`` right now? No
        while the fault plane has it dead or preemption-warned, or the
        controller has it quarantined for flapping."""
        if self.faults is not None and not self.faults.placeable(i):
            return False
        if self.controller is not None and self.controller.is_quarantined(
                i, self.now):
            return False
        return True

    def fail_shard(self, i: int, at: float, *, reason: str = "crash",
                   final_snapshot: bool = False) -> Tuple[List[Job], int]:
        """Kill shard ``i`` at ``at``: the engine's :meth:`crash` credits
        checkpoints and returns the orphans; this layer emits the
        lifecycle events (``shard_failed`` + one ``job_orphaned`` per
        orphan, while the job still carries its runtime state so span
        folding can close truncated init/running spans), scrubs each
        orphan back to a pristine pending job, and hands it to the fault
        plane's retry scheduler."""
        orphans, lost = self.shards[i].crash(at, final_snapshot=final_snapshot)
        self._dispatch(EngineEvent(
            kind=SHARD_FAILED, time=at, shard=i,
            detail=f"{reason}: -{lost} GPUs, {len(orphans)} jobs orphaned"))
        for job in orphans:
            self._dispatch(EngineEvent(
                kind=JOB_ORPHANED, time=at, job=job, shard=i, detail=reason))
            self._scrub(job)
            self.placed.pop(job.job_id, None)
            if self.faults is not None:
                self.faults.on_orphaned(job, at)
        return orphans, lost

    def recover_shard(self, i: int, capacity: int, at: float) -> None:
        """Restore ``capacity`` cold GPUs to a failed shard at ``at``."""
        self.shards[i].restore(capacity, at)
        self._dispatch(EngineEvent(
            kind=SHARD_RECOVERED, time=at, shard=i,
            detail=f"+{capacity} GPUs restored"))

    def slow_shard(self, i: int, factor: float, at: float) -> None:
        """Apply (or clear, with ``factor=1.0``) a straggler step-time
        multiplier on shard ``i``."""
        self.shards[i].set_speed(factor, at)
        self._dispatch(EngineEvent(
            kind=SHARD_SLOWED, time=at, shard=i,
            detail=f"x{factor:g} step time"))

    def warn_shard(self, i: int, at: float, *, kill_at: float) -> None:
        """Announce a spot preemption of shard ``i`` (the lead-time
        window a failure-aware controller drains in)."""
        self._dispatch(EngineEvent(
            kind=SHARD_WARNED, time=at, shard=i,
            detail=f"spot preemption at t={kill_at:g}"))

    def requeue(self, job: Job, at: float, *, attempt: int = 1) -> bool:
        """Re-place an orphaned job through the fabric's placement at
        ``at``. Prefers admissible shards (alive, unwarned, not
        quarantined) but falls back to any shard with replica capacity;
        returns False — job untouched — only when no shard can hold one
        replica."""
        need = job.profile().gpus_per_replica
        eligible = [i for i, e in enumerate(self.shards)
                    if e.cfg.max_gpus >= need]
        healthy = [i for i in eligible if self.shard_admissible(i)]
        pool = healthy or eligible
        if not pool:
            return False
        sub = [self.shards[i] for i in pool]
        k = self._place(job, sub)
        i = pool[k] if 0 <= k < len(sub) else pool[0]
        job.restarts += 1
        self.placed[job.job_id] = i
        self.shards[i].admit_at(job, at)
        self._dispatch(EngineEvent(
            kind=JOB_RETRIED, time=at, job=job, shard=i,
            detail=f"attempt {attempt} -> shard {i}"))
        return True

    def shed_job(self, job: Job, at: float, reason: str) -> None:
        """Terminal failure outcome: record the job as violated (it will
        never run) and emit ``job_shed``. Exactly one terminal record
        per submitted job is the invariant the property tests pin."""
        self.placed.pop(job.job_id, None)
        self._shed_records.append(JobRecord(
            job=job, gpus=0, used_bank=False, start=float("inf"),
            finish=float("inf"), violated=True, wait=float("inf"),
            init_overhead=0.0))
        self._dispatch(EngineEvent(
            kind=JOB_SHED, time=at, job=job, shard=-1, detail=reason))

    def _scrub(self, job: Job) -> None:
        """Reset a killed job's runtime state so it re-enters placement
        as a pristine pending job (checkpointed ``iters_done`` and the
        ``restarts`` count survive — that is the recovery model)."""
        job.phase = JobPhase.PENDING
        job.start_time = None
        job.finish_time = None
        job.gpus = 0
        job.used_bank = False
        job.init_overhead = 0.0

    # -- introspection -------------------------------------------------------

    @property
    def now(self) -> float:
        """The fabric clock: the furthest-advanced shard."""
        return max(eng.now for eng in self.shards)

    @property
    def records(self):
        return ([r for eng in self.shards for r in eng.records]
                + list(self._shed_records))

    def result(self) -> SimResult:
        """Merged fleet-wide result so far (no draining side effects)."""
        return self._final_result([eng.result() for eng in self.shards])

    def summary(self) -> Dict[str, float]:
        return self.result().summary()

    def summary_by_tenant(self) -> Dict[str, Dict[str, float]]:
        return self.result().summary_by_tenant()
