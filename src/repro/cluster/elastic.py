"""Elastic control plane: work stealing, autoscaling, tenant quotas.

The paper's headline claim is an *elastic* Workload Scheduler that
reallocates resources fast enough to cut SLO violations 4.0-7.9x and
cost 1.6-4.5x. The static :class:`~repro.cluster.fabric.ClusterFabric`
places each job exactly once; a saturated shard then strands jobs while
neighbours idle. :class:`ElasticController` closes that gap with three
mechanisms, all acting *between* scheduling rounds through fabric verbs
(``migrate`` / ``resize_shard``), never inside a policy's round:

1. **Cross-shard work stealing** — pending jobs a saturated shard cannot
   serve with its currently free capacity are migrated to shards with
   headroom, respecting ``gpus_per_replica`` feasibility and preferring
   destinations whose warm pool already holds the job's LLM (warmth-
   aware: a steal to a warm shard pays the warm overhead, not a cold
   start).
2. **Queue-pressure autoscaling** — cold (free, unbilled) GPUs move from
   low-pressure donors to shards whose pressure stays above
   ``pressure_high`` for ``hysteresis_cycles`` consecutive control
   cycles; a per-shard ``autoscale_cooldown`` stops the fleet from
   thrashing. The fleet total is conserved; a shard shrunk to
   ``min_shard_gpus`` is effectively spun down.
3. **Per-tenant admission quotas** — a :class:`TenantQuota` caps a
   tenant's GPU-second budget, billed cost, and concurrently
   outstanding jobs. Enforcement is fleet-wide at submit time
   (completed ledgers + in-flight commitments + pending estimates);
   rejections surface as typed :data:`JOB_REJECTED` events and on the
   service's :class:`~repro.api.types.JobHandle`.

A fourth, supporting mechanism keeps elasticity affordable under the
serverless billing model (every warm GPU bills, busy or idle): each
cycle starts by returning warm GPUs idle longer than
``idle_reclaim_after`` to the unbilled cold pool, fleet-wide — far
earlier than the policy's own ``reclaim_window``.

The controller subscribes to the fabric-wide ``EngineEvent`` stream and
runs one control cycle per ``control_interval`` of simulated time,
keyed off ROUND events — fully deterministic, so elastic runs are
reproducible seed-for-seed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.cluster.engine import ROUND, ClusterEngine, EngineEvent
from repro.cluster.health import ShardHealth, fleet_health
from repro.cluster.policies.base import admission_key
from repro.core.jobs import Job, exec_time

# Fabric-level event kinds, alongside the engine's ARRIVAL/ROUND/JOB_DONE.
JOB_STOLEN = "job_stolen"          # a pending job migrated between shards
JOB_REJECTED = "job_rejected"      # a submission bounced off a tenant quota
SHARD_RESIZED = "shard_resized"    # autoscaler moved GPUs between shards

# Alert lifecycle kinds, emitted onto the same stream by the obs-layer
# AlertRules evaluator (repro.obs.alerts) via ``fabric.announce``. They
# are defined here — not in obs — so the controller (and the future
# SLO autotuner) can subscribe without a cluster->obs import cycle.
# ``detail`` starts with the firing rule's name: ``"<rule>: <why>"``.
ALERT_FIRED = "alert_fired"        # a rule's condition became true
ALERT_RESOLVED = "alert_resolved"  # the condition cleared

# Failure-aware audit action tags. Drains ride the job_stolen fabric
# event and sheds the job_shed event; quarantine is pure controller
# state, so it exists only in the audit log.
DRAIN = "drain"
QUARANTINE = "quarantine"
SHED = "job_shed"


@dataclass(frozen=True)
class TenantQuota:
    """Per-tenant admission caps; ``None`` leaves a dimension uncapped.

    ``gpu_seconds`` / ``cost_usd`` are *budgets*: a submission is
    rejected when the tenant's committed spend (completed ledger +
    running commitments + pending estimates) plus the new job's own
    estimate would exceed them. ``max_outstanding`` caps how many of the
    tenant's jobs may be queued or running at once."""

    gpu_seconds: Optional[float] = None
    cost_usd: Optional[float] = None
    max_outstanding: Optional[int] = None


@dataclass
class ElasticConfig:
    """Knobs of the elastic control plane."""

    control_interval: float = 2.0     # s of sim time between control cycles
    steal_enabled: bool = True
    autoscale_enabled: bool = True
    pressure_high: float = 1.25       # demand/capacity that marks saturation
    pressure_low: float = 0.25        # below this a shard may donate GPUs
    hysteresis_cycles: int = 1        # consecutive hot cycles before scaling
    autoscale_step: int = 8           # max GPUs a receiver gains per cycle
    autoscale_cooldown: float = 4.0   # s between resizes of the same shard
    min_shard_gpus: int = 1           # shrink floor (== spin-down at 1)
    idle_reclaim_after: Optional[float] = 3.0  # early warm->cold reclaim
    #   window, fleet-wide (None: only the policy's reclaim_window applies)
    max_steals_per_cycle: int = 16
    max_migrations_per_job: int = 3   # anti-thrash: stop bouncing a job
    steal_only_salvageable: bool = True  # steal only when the destination
    #   can still meet the job's SLO (warmth-adjusted completion estimate)
    quotas: Dict[str, TenantQuota] = field(default_factory=dict)
    # Failure awareness — all three act only when the fabric carries a
    # FaultPlane (repro.cluster.faults); without one they are inert.
    drain_on_warning: bool = True     # evacuate preemption-warned shards
    quarantine_enabled: bool = True   # bench flapping shards
    flap_threshold: int = 2           # failures within flap_window to trip
    flap_window: float = 300.0        # s of failure history considered
    quarantine_s: float = 120.0       # re-admission delay (extended while
    #   the shard keeps failing: health-gated, not a fixed timer)
    shed_enabled: bool = True         # degrade gracefully under capacity
    #   loss: drop best-effort jobs that are doomed anyway


def job_gpu_second_estimate(engine: ClusterEngine, job: Job) -> float:
    """A submission's committed-spend estimate for quota purposes: one
    warm replica for the job's full predicted execution."""
    prof = job.profile()
    need = prof.gpus_per_replica
    return need * exec_time(job, need, used_bank=engine.use_bank_for(job),
                            alloc_overhead=prof.warm_overhead)


class ElasticController:
    """Drives steal / autoscale / quota decisions for one fabric.

    Constructed by :class:`~repro.cluster.fabric.ClusterFabric` when
    ``elastic=`` is given; it subscribes itself to the fabric event
    stream and acts through ``fabric.migrate`` / ``fabric.resize_shard``.
    """

    def __init__(self, fabric, cfg: Optional[ElasticConfig] = None):
        self.fabric = fabric
        self.cfg = cfg or ElasticConfig()
        self.steals = 0                   # lifetime counters (introspection)
        self.resizes = 0
        self.rejections = 0
        self.drains = 0                   # jobs evacuated off warned shards
        self.quarantines = 0              # flapping shards benched
        self.sheds = 0                    # doomed best-effort jobs dropped
        # Optional decision sink (duck-typed as repro.obs.audit.AuditLog):
        # when attached — Telemetry.attach does it — every steal / resize
        # / rejection / reclaim records the ShardHealth inputs it acted
        # on, so control actions stay attributable to recorded signals.
        self.audit = None
        # rule name -> fire time for alerts currently firing (populated
        # only when an AlertRules evaluator is attached to the fabric)
        self.active_alerts: Dict[str, float] = {}
        self._next_cycle_at = 0.0
        self._hot_streak: Dict[int, int] = {}
        self._last_resize: Dict[int, float] = {}
        self._migrations: Dict[int, int] = {}   # job_id -> times stolen
        self._quarantined_until: Dict[int, float] = {}   # shard -> t
        self._in_cycle = False
        fabric.on_event(self._on_event)

    # -- quotas (submit-time admission) ---------------------------------------

    def set_quota(self, tenant: str, quota: TenantQuota) -> None:
        self.cfg.quotas[tenant] = quota

    def tenant_commitment(self, tenant: str) -> Tuple[float, float, int]:
        """Fleet-wide committed ``(gpu_seconds, cost_usd, outstanding)``
        for ``tenant``: completed ledgers, plus the full span of running
        jobs (their busy time settles onto the ledger only at
        completion), plus one-replica estimates for queued work."""
        gpu_s = cost = 0.0
        outstanding = 0
        for eng in self.fabric.shards:
            gpu_s += eng.gpu_seconds_by_tenant.get(tenant, 0.0)
            cost += eng.cost_by_tenant.get(tenant, 0.0)
            price = eng.cfg.price_per_gpu_s
            for job, gpus in eng.running.values():
                if job.tenant != tenant:
                    continue
                outstanding += 1
                fin = eng.finish_time_of(job.job_id)
                span = max((fin if fin is not None else eng.now)
                           - job.start_time, 0.0)
                gpu_s += gpus * span
                cost += gpus * span * price * job.slo_class.price_tier
            for job in eng.pending_jobs() + eng.queued_arrivals():
                if job.tenant != tenant:
                    continue
                outstanding += 1
                est = job_gpu_second_estimate(eng, job)
                gpu_s += est
                cost += est * price * job.slo_class.price_tier
        return gpu_s, cost, outstanding

    def admission_error(self, job: Job) -> Optional[str]:
        """``None`` if ``job`` may be admitted; else the rejection
        reason. Called by ``fabric.submit`` before placement."""
        quota = self.cfg.quotas.get(job.tenant)
        if quota is None:
            return None
        gpu_s, cost, outstanding = self.tenant_commitment(job.tenant)
        if (quota.max_outstanding is not None
                and outstanding >= quota.max_outstanding):
            return (f"tenant {job.tenant!r} at max outstanding jobs "
                    f"({outstanding} >= {quota.max_outstanding})")
        eng = self.fabric.shards[0]
        est = job_gpu_second_estimate(eng, job)
        if (quota.gpu_seconds is not None
                and gpu_s + est > quota.gpu_seconds):
            return (f"tenant {job.tenant!r} GPU-second budget exceeded "
                    f"({gpu_s:.0f} committed + {est:.0f} est "
                    f"> {quota.gpu_seconds:.0f})")
        est_cost = (est * eng.cfg.price_per_gpu_s
                    * job.slo_class.price_tier)
        if quota.cost_usd is not None and cost + est_cost > quota.cost_usd:
            return (f"tenant {job.tenant!r} cost cap exceeded "
                    f"(${cost:.2f} committed + ${est_cost:.2f} est "
                    f"> ${quota.cost_usd:.2f})")
        return None

    # -- control loop ----------------------------------------------------------

    def _on_event(self, ev: EngineEvent) -> None:
        if ev.kind in (ALERT_FIRED, ALERT_RESOLVED):
            name = (ev.detail or "").split(":", 1)[0].strip()
            if ev.kind == ALERT_FIRED:
                self.active_alerts[name] = ev.time
                # pressure relief: drop the interval gate so the very
                # next ROUND runs a control cycle instead of waiting
                # out the remainder of control_interval
                self._next_cycle_at = min(self._next_cycle_at, ev.time)
            else:
                self.active_alerts.pop(name, None)
            return
        if ev.kind != ROUND or self._in_cycle:
            return
        if ev.time < self._next_cycle_at:
            return
        self._next_cycle_at = ev.time + self.cfg.control_interval
        # steals/resizes emit fabric events, which re-enter this
        # subscriber; the guard keeps a cycle from triggering itself
        self._in_cycle = True
        try:
            self.control_cycle(ev.time)
        finally:
            self._in_cycle = False

    def _fleet_health(self) -> List[ShardHealth]:
        return fleet_health(self.fabric.shards,
                            getattr(self.fabric, "faults", None))

    def control_cycle(self, t: float) -> None:
        """One deterministic control decision at sim time ``t``."""
        if len(self.fabric.shards) < 2:
            return
        healths = self._fleet_health()
        # Reclaim first: idle warm GPUs return to cold early (billing
        # stops), making low-pressure shards better donors below.
        self._reclaim_idle(t, healths)
        # Failure awareness next: quarantine flappers, evacuate
        # preemption-warned shards, shed doomed best-effort load — all
        # before autoscale/steal read their pressure snapshot, so the
        # healthy mechanisms never route work toward dying capacity.
        faults = getattr(self.fabric, "faults", None)
        if faults is not None:
            self._failure_cycle(t, healths, faults)
            healths = self._fleet_health()
        # Autoscale first, on the undisturbed pressure snapshot: moving
        # cold capacity toward saturated shards keeps their warm pools
        # consolidated (cheap). Stealing then spreads only the overflow
        # the grown shard still cannot serve — if steals ran first they
        # would drain the very queue-pressure signal the autoscaler
        # needs, and the fleet would converge to scattered cold starts.
        if self.cfg.autoscale_enabled:
            self._autoscale_cycle(t, healths)
        if self.cfg.steal_enabled:
            # re-snapshot: resizes changed capacity and free pools
            self._steal_cycle(t, self._fleet_health())

    # -- failure awareness (active only with a FaultPlane on the fabric) -------

    def is_quarantined(self, shard: int, t: float) -> bool:
        """Is ``shard`` currently benched for flapping? Consulted by
        ``fabric.shard_admissible`` (placement + retries) and by the
        steal/autoscale destination filters."""
        return t < self._quarantined_until.get(shard, float("-inf"))

    def _failure_cycle(self, t: float, healths: List[ShardHealth],
                       faults) -> None:
        cfg = self.cfg
        if cfg.quarantine_enabled:
            self._quarantine_cycle(t, faults)
        if cfg.drain_on_warning and faults.warned:
            self._drain_cycle(t, healths, faults)
        if cfg.shed_enabled and faults.capacity_lost() > 0:
            self._shed_cycle(t, faults)

    def _quarantine_cycle(self, t: float, faults) -> None:
        """Bench shards whose recent failure count marks them as
        flapping. Re-admission is health-gated, not a fixed timer: a
        shard that keeps failing inside the window has its bench
        extended every cycle, and only ages back in once its failure
        history clears ``flap_window``."""
        cfg = self.cfg
        for i in range(len(self.fabric.shards)):
            if faults.is_down(i):
                continue               # dead shards need no bench
            fails = faults.recent_failures(i, t, cfg.flap_window)
            if fails < cfg.flap_threshold:
                continue
            newly = not self.is_quarantined(i, t)
            self._quarantined_until[i] = max(
                self._quarantined_until.get(i, float("-inf")),
                t + cfg.quarantine_s)
            if newly:
                self.quarantines += 1
                if self.audit is not None:
                    self.audit.decision(
                        time=t, action=QUARANTINE, shard=i,
                        detail=(f"{fails} failures in {cfg.flap_window:g}s "
                                f">= {cfg.flap_threshold}; benched until "
                                f"t={t + cfg.quarantine_s:g}"),
                        inputs={"recent_failures": fails})

    def _drain_cycle(self, t: float, healths: List[ShardHealth],
                     faults) -> None:
        """Proactively evacuate pending work off preemption-warned
        shards during the warning lead time — moved jobs restart from a
        queue, not from a crash, so no retry budget is spent and no
        checkpoint is lost."""
        shards = self.fabric.shards
        by_shard = {h.shard: h for h in healths}
        free = {h.shard: h.free_capacity for h in healths}
        for src in sorted(faults.warned):
            for job in list(shards[src].pending_jobs()):
                need = job.profile().gpus_per_replica
                best = None
                best_key = None
                for h in healths:
                    dst = h.shard
                    if (dst == src or shards[dst].cfg.max_gpus < need
                            or not self.fabric.shard_admissible(dst)):
                        continue
                    if free[dst] < need:
                        # a drain only beats the orphan->retry path when
                        # the destination can actually start the job;
                        # pushing evacuees into a saturated queue just
                        # trades one wait for another and forfeits the
                        # warned shard's remaining lead-time throughput
                        continue
                    warm = len(shards[dst].pool(job.llm).idle) >= need
                    key = (warm, free[dst], -dst)
                    if best_key is None or key > best_key:
                        best, best_key = dst, key
                if best is None:
                    continue           # nowhere to go: the crash path
                #   (orphan -> retry) will pick the job up instead
                if self.fabric.migrate(job.job_id, best, at=t):
                    free[best] -= need
                    self.drains += 1
                    if self.audit is not None:
                        self.audit.decision(
                            time=t, action=DRAIN, shard=best,
                            job_id=job.job_id, tenant=job.tenant,
                            detail=(f"evacuated shard {src} (preemption "
                                    f"warned) -> {best}"),
                            inputs={"src": by_shard[src],
                                    "dst": by_shard[best]})

    def _shed_cycle(self, t: float, faults) -> None:
        """Graceful degradation while the fleet is short on capacity:
        drop pending *best-effort* jobs that would miss their SLO even
        if started right now at the maximum feasible replica count —
        they can only burn GPUs premium/standard jobs need, and their
        violation is already certain."""
        gmax = max(e.cfg.max_gpus for e in self.fabric.shards)
        for eng in self.fabric.shards:
            for job in list(eng.pending_jobs()):
                if job.slo_class.priority >= 0:
                    continue           # only best-effort class is shed
                prof = job.profile()
                gpus = min(eng.cfg.max_replicas_per_job
                           * prof.gpus_per_replica, max(gmax, 1))
                if gpus < prof.gpus_per_replica:
                    continue
                best_fin = t + exec_time(
                    job, gpus, used_bank=eng.use_bank_for(job),
                    alloc_overhead=prof.warm_overhead)
                if best_fin <= job.deadline:
                    continue           # still salvageable: keep it
                if eng.extract_pending(job.job_id) is None:
                    continue
                self.sheds += 1
                if self.audit is not None:
                    self.audit.decision(
                        time=t, action=SHED, shard=-1, job_id=job.job_id,
                        tenant=job.tenant,
                        detail=(f"best-effort job doomed (best finish "
                                f"{best_fin:.0f} > deadline "
                                f"{job.deadline:.0f}) while fleet is "
                                f"{faults.capacity_lost()} GPUs short"))
                self.fabric.shed_job(job, t,
                                     "degraded fleet: doomed best-effort "
                                     "load shed")
        # Second stage: doomed best-effort jobs *holding GPUs* while
        # higher classes queue on the same shard. Their violation is
        # already certain (scheduled finish past deadline), so every
        # extra second they run starves salvageable premium/standard
        # work of capacity the degraded fleet no longer has — kill them
        # and let the queue claim the GPUs at the next round. The
        # terminal record is a violated shed either way.
        for eng in self.fabric.shards:
            if not any(j.slo_class.priority >= 0
                       for j in eng.pending_jobs()):
                continue
            for job_id, (job, gpus) in list(eng.running.items()):
                if job.slo_class.priority >= 0:
                    continue
                fin = eng.finish_time_of(job_id)
                if fin is None or fin <= job.deadline:
                    continue
                if eng.cancel_running(job_id, t) is None:
                    continue
                eng.ensure_round(t)
                self.sheds += 1
                if self.audit is not None:
                    self.audit.decision(
                        time=t, action=SHED, shard=-1, job_id=job.job_id,
                        tenant=job.tenant,
                        detail=(f"doomed running best-effort job "
                                f"(scheduled finish {fin:.0f} > deadline "
                                f"{job.deadline:.0f}) preempted for "
                                f"queued premium/standard work"))
                self.fabric.shed_job(job, t,
                                     "degraded fleet: doomed running "
                                     "best-effort job preempted")

    # -- mechanism 0: early fleet-wide idle reclaim ----------------------------

    def _reclaim_idle(self, t: float, healths: List[ShardHealth]) -> None:
        """Billing control: warm GPUs idle for more than
        ``idle_reclaim_after`` seconds return to the (unbilled) cold
        pool now, on every shard, instead of waiting out the policy's
        full ``reclaim_window``. Serverless billing charges for every
        warm GPU, so spread-out elastic fleets would otherwise pay for
        warm pools the next burst may never revisit; a busy shard is
        naturally untouched (its pools have no idle GPUs to take)."""
        window = self.cfg.idle_reclaim_after
        if window is None:
            return
        for h in healths:
            if h.warm_idle > 0:
                n = self.fabric.shards[h.shard].view.mature_and_reclaim(
                    window)
                if n > 0 and self.audit is not None:
                    self.audit.decision(
                        time=t, action="idle_reclaim", shard=h.shard,
                        detail=f"{n} warm GPUs idle > {window:g}s -> cold",
                        inputs={"shard": h})

    # -- mechanism 1: cross-shard work stealing --------------------------------

    def _overflow_jobs(self, eng: ClusterEngine, h: ShardHealth) -> List[Job]:
        """Pending jobs beyond what the shard's currently free capacity
        can serve, in admission order: the shard keeps the highest-
        priority prefix it can cover; the tail is steal-eligible.
        In-flight warming GPUs count as local capacity — the policy has
        already paid their cold start for exactly these jobs, and
        stealing them away would strand freshly warmed (billed) GPUs."""
        jobs = sorted(eng.pending_jobs(), key=admission_key)
        warming = sum(len(p.warming) for p in eng.pools.values())
        local = h.cold_free + h.warm_idle + warming
        overflow: List[Job] = []
        for job in jobs:
            need = job.profile().gpus_per_replica
            if local >= need:
                local -= need
            else:
                overflow.append(job)
        return overflow

    def _steal_cycle(self, t: float, healths: List[ShardHealth]) -> None:
        shards = self.fabric.shards
        by_shard = {h.shard: h for h in healths}
        free = {h.shard: h.free_capacity for h in healths}
        moves = 0
        for h in sorted(healths, key=lambda x: x.pressure, reverse=True):
            if h.pressure <= self.cfg.pressure_high or h.pending_jobs == 0:
                break
            src = h.shard
            for job in self._overflow_jobs(shards[src], h):
                if moves >= self.cfg.max_steals_per_cycle:
                    return
                if (self._migrations.get(job.job_id, 0)
                        >= self.cfg.max_migrations_per_job):
                    continue
                prof = job.profile()
                need = prof.gpus_per_replica
                best = None
                best_key = None
                for hd in healths:
                    dst = hd.shard
                    if dst == src or shards[dst].cfg.max_gpus < need:
                        continue
                    if free[dst] < need:
                        continue
                    if not self.fabric.shard_admissible(dst):
                        continue   # dead / warned / quarantined shard
                    warm = len(shards[dst].pool(job.llm).idle) >= need
                    if self.cfg.steal_only_salvageable:
                        # SLO-aware: move only where the (warmth-
                        # adjusted) completion still makes the deadline.
                        # A job no destination can save stays queued —
                        # its demand keeps the autoscaler's pressure
                        # signal honest instead of paying a pointless
                        # cold start elsewhere.
                        ov = (prof.warm_overhead if warm
                              else prof.cold_overhead)
                        fin = t + exec_time(
                            job, need,
                            used_bank=shards[dst].use_bank_for(job),
                            alloc_overhead=ov)
                        if fin > job.deadline:
                            continue
                    key = (warm, free[dst], -dst)   # warmth, then headroom
                    if best_key is None or key > best_key:
                        best, best_key = dst, key
                if best is None:
                    continue
                if self.fabric.migrate(job.job_id, best, at=t):
                    free[best] -= need
                    free[src] += need
                    self._migrations[job.job_id] = (
                        self._migrations.get(job.job_id, 0) + 1)
                    moves += 1
                    self.steals += 1
                    if self.audit is not None:
                        self.audit.decision(
                            time=t, action=JOB_STOLEN, shard=best,
                            job_id=job.job_id, tenant=job.tenant,
                            detail=f"shard {src} -> {best}",
                            inputs={"src": h, "dst": by_shard[best]})

    # -- mechanism 2: queue-pressure autoscaling -------------------------------

    def _shrink_floor(self, eng: ClusterEngine) -> int:
        """Never shrink a shard below the replica unit of any job routed
        to it (pending or still-queued arrival) — a shard smaller than a
        queued job's replica would insta-violate it on arrival."""
        need = self.cfg.min_shard_gpus
        for job in eng.pending_jobs() + eng.queued_arrivals():
            need = max(need, job.profile().gpus_per_replica)
        return need

    def _autoscale_cycle(self, t: float, healths: List[ShardHealth]) -> None:
        cfg = self.cfg
        shards = self.fabric.shards
        for h in healths:
            if h.pressure > cfg.pressure_high:
                self._hot_streak[h.shard] = self._hot_streak.get(h.shard, 0) + 1
            else:
                self._hot_streak[h.shard] = 0

        def cooled(i: int) -> bool:
            return t - self._last_resize.get(i, -1e18) >= cfg.autoscale_cooldown

        receivers = [h for h in healths
                     if self._hot_streak.get(h.shard, 0) >= cfg.hysteresis_cycles
                     and cooled(h.shard)
                     and self.fabric.shard_admissible(h.shard)]
        donors = [h for h in healths
                  if h.pressure < cfg.pressure_low and cooled(h.shard)
                  and h.cold_free > 0]
        if not receivers or not donors:
            return
        receivers.sort(key=lambda x: x.pressure, reverse=True)
        donors.sort(key=lambda x: (x.pressure, -x.cold_free))
        spare = {d.shard: max(0, min(d.cold_free,
                                     d.gpus - self._shrink_floor(
                                         shards[d.shard])))
                 for d in donors}
        for r in receivers:
            want = cfg.autoscale_step
            for d in donors:
                if want <= 0:
                    break
                if d.shard == r.shard or spare[d.shard] <= 0:
                    continue
                k = min(want, spare[d.shard])
                before = shards[d.shard].cfg.max_gpus
                after = self.fabric.resize_shard(d.shard, before - k, at=t)
                moved = before - after   # shrink clamps to the cold pool
                if moved <= 0:
                    spare[d.shard] = 0
                    continue
                r_before = shards[r.shard].cfg.max_gpus
                r_after = self.fabric.resize_shard(r.shard, r_before + moved,
                                                   at=t)
                if self.audit is not None:
                    # one audit entry per emitted SHARD_RESIZED event,
                    # each carrying the pre-decision health snapshots
                    self.audit.decision(
                        time=t, action=SHARD_RESIZED, shard=d.shard,
                        detail=(f"{before} -> {after} GPUs (donor; "
                                f"pressure {d.pressure:.2f} < "
                                f"{cfg.pressure_low:g})"),
                        inputs={"shard": d, "receiver": r})
                    self.audit.decision(
                        time=t, action=SHARD_RESIZED, shard=r.shard,
                        detail=(f"{r_before} -> {r_after} GPUs (receiver; "
                                f"pressure {r.pressure:.2f} > "
                                f"{cfg.pressure_high:g} for "
                                f"{self._hot_streak.get(r.shard, 0)} "
                                f"cycles)"),
                        inputs={"shard": r, "donor": d})
                spare[d.shard] -= moved
                want -= moved
                self.resizes += 1
                self._last_resize[d.shard] = t
                self._last_resize[r.shard] = t
