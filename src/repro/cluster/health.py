"""Per-shard pressure signals for the elastic control plane.

A :class:`ShardHealth` is an immutable snapshot of one
:class:`~repro.cluster.engine.ClusterEngine` shard, computed between
scheduling rounds: queue depth and GPU demand of the pending queues,
committed (running) GPUs, free warm/cold capacity, and projected
deadline slack. :func:`fleet_health` snapshots every shard of a fabric
at once so the :class:`~repro.cluster.elastic.ElasticController` can
compare shards on a consistent basis.

The headline signal is ``pressure`` — outstanding GPU demand (pending +
running) normalized by shard capacity. ``pressure > 1`` means the shard
cannot serve its queue even if everything it owns were free;
sustained high pressure next to idle neighbours is exactly the
imbalance the paper's elastic Workload Scheduler removes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

from repro.cluster.engine import ClusterEngine
from repro.core.jobs import exec_time


@dataclass(frozen=True)
class ShardHealth:
    """One shard's load/capacity snapshot at ``now``."""

    shard: int
    now: float
    gpus: int                  # shard capacity (cfg.max_gpus)
    cold_free: int             # free cold GPUs (unbilled)
    warm_idle: int             # idle warm GPUs across all LLM pools
    warm_total: int            # idle + warming + busy warm GPUs
    running_gpus: int          # GPUs committed to running jobs
    pending_jobs: int          # jobs sitting in pending queues
    pending_gpu_demand: int    # sum of one-replica GPU needs over pending
    late_pending: int          # pending jobs whose best-case finish misses SLO
    min_slack: float           # tightest projected deadline slack (inf if idle)
    # failure-plane signals (defaults describe a fault-free shard)
    alive: bool = True         # False once the fault plane killed the shard
    draining: bool = False     # inside a spot-preemption warning window
    recent_failures: int = 0   # crash/preempt count in the flap window

    @property
    def pressure(self) -> float:
        """Outstanding GPU demand per owned GPU. > 1: over-committed."""
        return (self.pending_gpu_demand + self.running_gpus) / max(self.gpus, 1)

    @property
    def free_capacity(self) -> int:
        """GPUs a newly placed job could claim this round (cold + idle
        warm), net of the demand already queued here."""
        return self.cold_free + self.warm_idle - self.pending_gpu_demand


def projected_slack(engine: ClusterEngine, job) -> float:
    """Projected deadline slack if ``job`` started *now* on one warm
    replica: ``deadline - now - T_warm(1)``. Negative means the shard
    can no longer meet the SLO without multi-replica catch-up."""
    prof = job.profile()
    t = exec_time(job, prof.gpus_per_replica,
                  used_bank=engine.use_bank_for(job),
                  alloc_overhead=prof.warm_overhead)
    return job.deadline - engine.now - t


def shard_health(engine: ClusterEngine, shard: int = 0,
                 faults=None, *, flap_window: float = 300.0) -> ShardHealth:
    """Snapshot one engine shard's pressure signals. Pass the fabric's
    :class:`~repro.cluster.faults.FaultPlane` to fill the failure
    signals (alive / draining / recent failure count); without one the
    snapshot describes a fault-free shard."""
    warm_idle = sum(len(p.idle) for p in engine.pools.values())
    warm_total = sum(p.total() for p in engine.pools.values())
    running_gpus = sum(g for _, g in engine.running.values())
    demand = 0
    late = 0
    min_slack = float("inf")
    n_pending = 0
    for queue in engine.pending.values():
        for job in queue:
            n_pending += 1
            demand += job.profile().gpus_per_replica
            slack = projected_slack(engine, job)
            min_slack = min(min_slack, slack)
            if slack < 0.0:
                late += 1
    return ShardHealth(
        shard=shard,
        now=engine.now,
        gpus=engine.cfg.max_gpus,
        cold_free=engine.cold_free,
        warm_idle=warm_idle,
        warm_total=warm_total,
        running_gpus=running_gpus,
        pending_jobs=n_pending,
        pending_gpu_demand=demand,
        late_pending=late,
        min_slack=min_slack,
        alive=faults is None or not faults.is_down(shard),
        draining=faults is not None and shard in faults.warned,
        recent_failures=(0 if faults is None else
                         faults.recent_failures(shard, engine.now,
                                                flap_window)),
    )


def fleet_health(shards: Sequence[ClusterEngine],
                 faults=None) -> List[ShardHealth]:
    """One :class:`ShardHealth` per shard, in shard order."""
    return [shard_health(eng, i, faults) for i, eng in enumerate(shards)]
