"""Deprecated module kept for import compatibility.

The discrete-event mechanism now lives in :mod:`repro.cluster.engine`
(:class:`ClusterEngine` + :class:`ResourceView`); the system-specific
scheduling logic lives in :mod:`repro.cluster.policies`. ``ClusterSim``
remains as an alias of :class:`ClusterEngine` — legacy subclasses that
override ``_schedule`` keep working, but new systems should be written
as :class:`~repro.cluster.policies.SchedulingPolicy` classes and built
via ``policies.build(name, cfg)``.
"""
from repro.cluster.engine import (
    ARRIVAL,
    JOB_DONE,
    ROUND,
    ClusterEngine,
    ClusterSim,
    JobRecord,
    ResourceView,
    SimConfig,
    SimResult,
    WarmPool,
)

__all__ = [
    "ClusterEngine",
    "ClusterSim",
    "JobRecord",
    "ResourceView",
    "SimConfig",
    "SimResult",
    "WarmPool",
]
