"""Discrete-event cluster simulator for LPT scheduling (§4.4, §6).

The simulator advances an event heap (arrivals / scheduler rounds / job
completions / warm-up completions) and accrues resource cost continuously
as ``billed_gpus * dt * price``. Systems (PromptTuner, INFless,
ElasticFlow) subclass :class:`ClusterSim` and implement ``_schedule()``,
which fires every ``round_interval`` seconds (paper §5.3: 50 ms rounds;
the default here is coarser purely to keep event counts small — results
are insensitive below ~1 s because job durations are seconds-to-minutes).

Execution model (calibrated by §2.2's characterization):
    finish = start + alloc_overhead [+ bank_lookup] + iters * iter_time(g)
with near-linear scaling ``iter_time(g)`` from ``repro.core.jobs`` (comm
is 0.4-0.5 % per extra replica — Fig 2a). Allocation is non-preemptive:
the GPU count is fixed at job start, matching Algorithms 1/2 which decide
allocations for *pending* jobs only.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.jobs import (
    GPU_PRICE_PER_S,
    LLM_PROFILES,
    STORAGE_PRICE_PER_JOB_S,
    Job,
    JobPhase,
    LLMProfile,
    exec_time,
    iter_time,
)

ARRIVAL, ROUND, JOB_DONE, WARM_READY = "arrival", "round", "job_done", "warm_ready"


@dataclass
class SimConfig:
    max_gpus: int = 32                 # cold-pool size / cluster size
    round_interval: float = 0.5        # scheduler round period (s)
    reclaim_window: float = 60.0       # idle warm GPU -> cold after this (s)
    keep_alive: float = 60.0           # INFless instance keep-alive (s)
    price_per_gpu_s: float = GPU_PRICE_PER_S
    latency_budget_frac: float = 0.2   # §4.4.3
    use_bank: bool = True              # prompt reusing on/off (Fig 8a/b)
    use_warm: bool = True              # runtime reusing on/off
    use_warm_allocator: bool = True    # simultaneous multi-GPU alloc (Table 8)
    use_delay: bool = True             # DelaySchedulable on/off (Table 8)
    use_latency_budget: bool = True    # Table 8 'w/o Latency Budget'
    max_replicas_per_job: int = 16
    best_effort: bool = True           # run SLO-infeasible jobs when idle


@dataclass
class JobRecord:
    job: Job
    gpus: int
    used_bank: bool
    start: float
    finish: float
    violated: bool
    wait: float                        # queueing delay
    init_overhead: float               # allocation / instance-init share


@dataclass
class SimResult:
    records: List[JobRecord]
    cost: float
    gpu_seconds: float
    makespan: float
    util_samples: List[Tuple[float, float]] = field(default_factory=list)

    @property
    def slo_violation(self) -> float:
        if not self.records:
            return 0.0
        return sum(r.violated for r in self.records) / len(self.records)

    def summary(self) -> Dict[str, float]:
        return {
            "jobs": len(self.records),
            "slo_violation_pct": 100.0 * self.slo_violation,
            "cost_usd": self.cost,
            "gpu_seconds": self.gpu_seconds,
            "makespan_s": self.makespan,
        }


class WarmPool:
    """Per-LLM warm GPU pool: idle (with idle-since), warming (ready-at),
    and busy counts. All GPUs in the pool are billed."""

    def __init__(self) -> None:
        self.idle: List[float] = []        # idle_since per idle GPU
        self.warming: List[float] = []     # ready_at (heap)
        self.busy: int = 0

    def total(self) -> int:
        return len(self.idle) + len(self.warming) + self.busy

    def take_idle(self, n: int) -> int:
        """Claim up to n idle GPUs; returns how many were claimed."""
        n = min(n, len(self.idle))
        # take the most recently idle ones (LIFO keeps cold candidates old)
        for _ in range(n):
            self.idle.pop()
        self.busy += n
        return n

    def release(self, n: int, now: float) -> None:
        self.busy -= n
        assert self.busy >= 0
        self.idle.extend([now] * n)

    def mature(self, now: float) -> None:
        """Move warming GPUs whose ready_at has passed into idle."""
        ready = [t for t in self.warming if t <= now + 1e-9]
        self.warming = [t for t in self.warming if t > now + 1e-9]
        self.idle.extend([now] * len(ready))

    def reclaim(self, now: float, window: float) -> int:
        """Return idle GPUs unused for `window` seconds to the cold pool."""
        keep = [t for t in self.idle if now - t < window]
        n = len(self.idle) - len(keep)
        self.idle = keep
        return n


class ClusterSim:
    """Event-driven base simulator; subclasses implement `_schedule`."""

    name = "base"

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.now = 0.0
        self._seq = itertools.count()
        self._events: List[Tuple[float, int, str, Any]] = []
        self.pending: Dict[str, List[Job]] = {}
        self.running: Dict[int, Tuple[Job, int]] = {}    # job_id -> (job, gpus)
        self.records: List[JobRecord] = []
        self.cost = 0.0
        self.gpu_seconds = 0.0
        self.cold_free = cfg.max_gpus
        self.pools: Dict[str, WarmPool] = {}
        self.util_samples: List[Tuple[float, float]] = []
        self._last_round = -1e9

    # -- billing hooks --------------------------------------------------------

    def billed_gpus(self) -> int:
        """GPUs currently accruing cost. Default: all warm-pool GPUs."""
        return sum(p.total() for p in self.pools.values())

    def _advance(self, t: float) -> None:
        dt = t - self.now
        if dt > 0:
            g = self.billed_gpus()
            self.cost += g * dt * self.cfg.price_per_gpu_s
            self.gpu_seconds += g * dt
            self.now = t

    # -- event plumbing --------------------------------------------------------

    def _push(self, t: float, kind: str, payload: Any = None) -> None:
        heapq.heappush(self._events, (t, next(self._seq), kind, payload))

    def pool(self, llm: str) -> WarmPool:
        if llm not in self.pools:
            self.pools[llm] = WarmPool()
        return self.pools[llm]

    # -- job lifecycle ----------------------------------------------------------

    def use_bank_for(self, job: Job) -> bool:
        """§4.4.3 latency budget: run the Prompt Bank only if its lookup
        latency fits within 20 % of the job's latency SLO."""
        if not self.cfg.use_bank:
            return False
        if not self.cfg.use_latency_budget:
            return True                    # Table 8: bank for EVERY request
        return job.profile().bank_lookup_s <= self.cfg.latency_budget_frac * job.slo

    def start_job(self, job: Job, gpus: int, alloc_overhead: float,
                  used_bank: bool) -> None:
        prof = job.profile()
        dur = exec_time(job, gpus, used_bank=used_bank,
                        alloc_overhead=alloc_overhead)
        job.phase = JobPhase.RUNNING
        job.start_time = self.now
        job.gpus = gpus
        job.used_bank = used_bank
        job.init_overhead = alloc_overhead + (
            prof.bank_lookup_s if used_bank else 0.0
        )
        self.running[job.job_id] = (job, gpus)
        self._push(self.now + dur, JOB_DONE, job)
        if gpus > prof.gpus_per_replica:   # multi-replica => storage channel
            self.cost += STORAGE_PRICE_PER_JOB_S * dur

    def _complete(self, job: Job) -> None:
        job.phase = JobPhase.DONE
        job.finish_time = self.now
        _, gpus = self.running.pop(job.job_id)
        self._on_job_done(job, gpus)
        self.records.append(
            JobRecord(
                job=job,
                gpus=gpus,
                used_bank=job.used_bank,
                start=job.start_time,
                finish=self.now,
                violated=self.now > job.deadline + 1e-9,
                wait=job.start_time - job.submit_time,
                init_overhead=getattr(job, "init_overhead", 0.0),
            )
        )

    # -- subclass hooks ------------------------------------------------------------

    def _on_job_done(self, job: Job, gpus: int) -> None:
        self.pool(job.llm).release(gpus, self.now)

    def _schedule(self) -> None:
        raise NotImplementedError

    def _maintain(self) -> None:
        """Round upkeep: mature warming GPUs, reclaim idle ones."""
        for llm, p in self.pools.items():
            p.mature(self.now)
            n = p.reclaim(self.now, self.cfg.reclaim_window)
            self.cold_free += n

    # -- main loop --------------------------------------------------------------------

    def run(self, jobs: List[Job]) -> SimResult:
        for j in jobs:
            self._push(j.submit_time, ARRIVAL, j)
        self._push(0.0, ROUND)
        while self._events:
            t, _, kind, payload = heapq.heappop(self._events)
            self._advance(t)
            if kind == ARRIVAL:
                self.pending.setdefault(payload.llm, []).append(payload)
            elif kind == JOB_DONE:
                self._complete(payload)
            elif kind == ROUND:
                self._maintain()
                self._schedule()
                self.util_samples.append(
                    (self.now, sum(g for _, g in self.running.values()))
                )
                outstanding = (
                    any(self.pending.values())
                    or self.running
                    or any(k == ARRIVAL for _, _, k, _ in self._events)
                )
                if outstanding and self.now < 24 * 3600:   # hard horizon
                    self._push(self.now + self.cfg.round_interval, ROUND)
            elif kind == WARM_READY:
                pass                       # pools mature lazily in _maintain
        # drain: anything still pending at sim end is a violation
        for q in self.pending.values():
            for j in q:
                self.records.append(
                    JobRecord(job=j, gpus=0, used_bank=False,
                              start=float("inf"), finish=float("inf"),
                              violated=True, wait=float("inf"),
                              init_overhead=0.0)
                )
        return SimResult(
            records=self.records,
            cost=self.cost,
            gpu_seconds=self.gpu_seconds,
            makespan=self.now,
            util_samples=self.util_samples,
        )
