"""ElasticFlow [41] (§3, §6.1) — SLO-aware elastic DL *training* system:

  * a statically provisioned fixed-size cluster (all ``max_gpus`` billed
    for the whole experiment — Inefficiency 1),
  * deadline-ordered admission with minimum-satisfactory-share
    allocation (its core algorithm),
  * elastic (it can choose any GPU count), but every job start pays the
    cold bring-up: no runtime reuse across jobs.
"""
from __future__ import annotations

from typing import List

from repro.cluster.engine import ResourceView, SimConfig
from repro.cluster.policies.base import (
    SchedulingPolicy,
    min_replicas_for_slo,
    register,
)
from repro.core.jobs import Job, exec_time


@register
class ElasticFlowPolicy(SchedulingPolicy):
    name = "elasticflow"

    def __init__(self, cfg: SimConfig):
        super().__init__(cfg)
        self.free = cfg.max_gpus          # policy-local: static cluster share

    def billed_gpus(self, view: ResourceView) -> int:
        return self.cfg.max_gpus          # static provisioning: always billed

    def maintain(self, view: ResourceView) -> None:
        pass                              # no pools to mature/reclaim

    def on_job_done(self, job: Job, gpus: int, view: ResourceView) -> None:
        self.free += gpus

    def on_round(self, view: ResourceView) -> None:
        # global deadline order (ElasticFlow's admission control)
        all_pending: List[Job] = [j for q in view.pending.values() for j in q]
        all_pending.sort(key=lambda j: j.deadline)
        started = set()
        for job in all_pending:
            prof = job.profile()
            used_bank = view.use_bank_for(job)
            slo_rem = view.slo_remaining(job)
            max_rep = min(self.free // prof.gpus_per_replica,
                          self.cfg.max_replicas_per_job)
            if max_rep < 1:
                continue
            a, feasible = min_replicas_for_slo(
                job, used_bank=used_bank, slo_rem=slo_rem, max_rep=max_rep,
                overhead=prof.cold_overhead)
            g = a * prof.gpus_per_replica
            hopeless = exec_time(
                job, max_rep * prof.gpus_per_replica, used_bank=used_bank,
                alloc_overhead=prof.cold_overhead) > slo_rem
            if feasible or (hopeless and self.cfg.best_effort):
                if hopeless:
                    g = prof.gpus_per_replica     # best effort: min share
                self.free -= g
                # every start is a cold bring-up: no runtime reuse
                view.start_job(job, g, prof.cold_overhead, used_bank)
                started.add(job.job_id)
        for llm in view.pending:
            view.pending[llm] = [j for j in view.pending[llm]
                                 if j.job_id not in started]
