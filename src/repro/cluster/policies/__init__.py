"""String-keyed scheduling-policy registry (the policy half of the
policy/mechanism split).

    from repro.cluster import SimConfig, policies

    policies.available()                 # ['edf-cold', 'elasticflow', ...]
    cls = policies.get("prompttuner")    # policy class
    engine = policies.build("prompttuner", SimConfig(max_gpus=32))
    result = engine.run(jobs)
"""
from repro.cluster.policies.base import SchedulingPolicy, available, get, register

# importing a module registers its policies
from repro.cluster.policies.prompttuner import PromptTunerPolicy
from repro.cluster.policies.infless import INFlessPolicy
from repro.cluster.policies.elasticflow import ElasticFlowPolicy
from repro.cluster.policies.simple import EDFColdPolicy, FIFOPolicy


def build(name: str, cfg=None):
    """Engine + policy in one call: the standard way to stand up a
    system. Returns a ready-to-``run`` ClusterEngine."""
    from repro.cluster.engine import ClusterEngine, SimConfig
    cfg = cfg or SimConfig()
    return ClusterEngine(cfg, get(name)(cfg))


__all__ = [
    "EDFColdPolicy",
    "ElasticFlowPolicy",
    "FIFOPolicy",
    "INFlessPolicy",
    "PromptTunerPolicy",
    "SchedulingPolicy",
    "available",
    "build",
    "get",
    "register",
]
