"""Cheap reference baselines the registry makes nearly free to add.

**FIFO** — the naive serverless strawman: per-LLM FIFO order, one replica
per job, no SLO awareness. Reuses warm GPUs when idle ones exist
(paying the warm connect) and cold-starts otherwise; completed jobs
release into the warm pool and idle GPUs are reclaimed after the default
window. A floor for every SLO-aware system.

**EDF-cold** — classic earliest-deadline-first admission over a cold pool
only: globally deadline-sorted, minimum GPU share that meets the SLO
assuming a cold bring-up, GPUs returned straight to the cold pool on
completion (no runtime reuse, but also no idle billing). Isolates the
value of PromptTuner's warm pools: EDF-cold has the same admission
urgency-ordering but pays every bring-up.
"""
from __future__ import annotations

from typing import List

from repro.cluster.engine import ResourceView
from repro.cluster.policies.base import (
    SchedulingPolicy,
    min_replicas_for_slo,
    register,
)
from repro.core.jobs import Job


@register
class FIFOPolicy(SchedulingPolicy):
    name = "fifo"

    def on_round(self, view: ResourceView) -> None:
        for llm, queue in view.pending.items():
            if not queue:
                continue
            pool = view.pool(llm)
            prof = queue[0].profile()
            queue.sort(key=lambda j: j.submit_time)
            leftover: List[Job] = []
            for job in queue:
                g = prof.gpus_per_replica
                used_bank = view.use_bank_for(job)
                if len(pool.idle) >= g:
                    pool.take_idle(g)
                    view.start_job(job, g, prof.warm_overhead, used_bank)
                elif view.cold_free >= g:
                    view.claim_cold_busy(llm, g)
                    view.start_job(job, g, prof.cold_overhead, used_bank)
                else:
                    leftover.append(job)
            view.pending[llm] = leftover


@register
class EDFColdPolicy(SchedulingPolicy):
    name = "edf-cold"

    def maintain(self, view: ResourceView) -> None:
        pass                               # nothing warms or idles

    def on_job_done(self, job: Job, gpus: int, view: ResourceView) -> None:
        view.return_cold(job.llm, gpus)    # no runtime reuse

    def on_round(self, view: ResourceView) -> None:
        all_pending: List[Job] = [j for q in view.pending.values() for j in q]
        all_pending.sort(key=lambda j: j.deadline)
        started = set()
        for job in all_pending:
            prof = job.profile()
            used_bank = view.use_bank_for(job)
            slo_rem = view.slo_remaining(job)
            max_rep = min(view.cold_free // prof.gpus_per_replica,
                          self.cfg.max_replicas_per_job)
            if max_rep < 1:
                continue
            a, feasible = min_replicas_for_slo(
                job, used_bank=used_bank, slo_rem=slo_rem, max_rep=max_rep,
                overhead=prof.cold_overhead)
            g = a * prof.gpus_per_replica
            if not feasible:
                if not self.cfg.best_effort:
                    continue
                g = prof.gpus_per_replica  # best effort: min share
            view.claim_cold_busy(job.llm, g)
            view.start_job(job, g, prof.cold_overhead, used_bank)
            started.add(job.job_id)
        for llm in view.pending:
            view.pending[llm] = [j for j in view.pending[llm]
                                 if j.job_id not in started]
