"""SchedulingPolicy protocol + the string-keyed policy registry.

A policy is the **policy** half of the policy/mechanism split: it decides
*which* pending jobs get *how many* GPUs *when*, acting only through the
:class:`~repro.cluster.engine.ResourceView` verbs. The engine owns all
state and billing.

Register a new system with the decorator::

    @register
    class MyPolicy(SchedulingPolicy):
        name = "mine"
        def on_round(self, view): ...

    engine = policies.build("mine", SimConfig())
"""
from __future__ import annotations

from typing import Dict, List, Tuple, Type

from repro.cluster.engine import ResourceView, SimConfig
from repro.core.jobs import Job, exec_time


def admission_key(job: Job) -> Tuple[int, float]:
    """SLO-class-aware admission order: higher-priority service classes
    first, earliest deadline within a class. With a single class (all
    priorities equal) Python's stable sort makes this identical to pure
    EDF — which is what keeps the single-tenant goldens pinned."""
    return (-job.slo_class.priority, job.deadline)


def tenant_over_budget(view: ResourceView, job: Job, quota) -> bool:
    """Shard-local quota read: has ``job``'s tenant already burned its
    :class:`~repro.cluster.elastic.TenantQuota` budget *on this shard's
    ledgers*? Policies can use this to deprioritize (or refuse) work
    for over-budget tenants inside a round. Fleet-wide enforcement —
    including in-flight commitments across every shard — happens at
    submit time in the :class:`~repro.cluster.elastic.ElasticController`;
    this helper is the cheap, view-only approximation available to a
    policy that never sees beyond its own shard."""
    spent_s = view.tenant_gpu_seconds(job.tenant)
    if quota.gpu_seconds is not None and spent_s >= quota.gpu_seconds:
        return True
    spent_usd = view.tenant_cost(job.tenant)
    return quota.cost_usd is not None and spent_usd >= quota.cost_usd


def min_replicas_for_slo(job: Job, *, used_bank: bool, slo_rem: float,
                         max_rep: int, overhead: float) -> Tuple[int, bool]:
    """The admission loop shared by deadline-aware policies: the smallest
    replica count ``a`` in [1, max_rep] whose predicted completion
    (§4.4's upper bound, with a fixed allocation ``overhead``) fits the
    remaining SLO. Returns ``(a, feasible)``; when nothing fits, ``a``
    is ``max_rep`` and ``feasible`` is False. Caller ensures
    ``max_rep >= 1``."""
    prof = job.profile()
    a = 1
    while (exec_time(job, a * prof.gpus_per_replica, used_bank=used_bank,
                     alloc_overhead=overhead) > slo_rem and a < max_rep):
        a += 1
    feasible = exec_time(job, a * prof.gpus_per_replica, used_bank=used_bank,
                         alloc_overhead=overhead) <= slo_rem
    return a, feasible


class SchedulingPolicy:
    """Base policy: override :meth:`on_round`; the other hooks have
    sensible serverless defaults (warm-pool billing, release-to-warm on
    completion, reclaim after ``cfg.reclaim_window``)."""

    name = "base"

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg

    # -- required hook ---------------------------------------------------------

    def on_round(self, view: ResourceView) -> None:
        """Called every scheduler round, after :meth:`maintain`. Examine
        ``view.pending`` and start / warm up / delay jobs."""
        raise NotImplementedError

    # -- optional hooks --------------------------------------------------------

    def on_job_done(self, job: Job, gpus: int, view: ResourceView) -> None:
        """A job completed; decide where its GPUs go. Default: into the
        LLM's warm-idle set (runtime reuse)."""
        view.release(job.llm, gpus)

    def maintain(self, view: ResourceView) -> None:
        """Round upkeep before scheduling. Default: mature warming GPUs
        and reclaim those idle for >= ``cfg.reclaim_window`` seconds."""
        view.mature_and_reclaim(self.cfg.reclaim_window)

    def billed_gpus(self, view: ResourceView) -> int:
        """GPUs accruing cost right now. Default: every warm-pool GPU
        (idle, warming or busy) — serverless-style billing."""
        return view.total_warm()


_REGISTRY: Dict[str, Type[SchedulingPolicy]] = {}


def register(cls: Type[SchedulingPolicy]) -> Type[SchedulingPolicy]:
    """Class decorator: add ``cls`` to the registry under ``cls.name``."""
    key = cls.name
    if not key or key == "base":
        raise ValueError(f"{cls.__name__} needs a unique `name` attribute")
    _REGISTRY[key] = cls
    return cls


def get(name: str) -> Type[SchedulingPolicy]:
    """Look up a policy class by its registry key."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown policy {name!r}; available: {sorted(_REGISTRY)}"
        ) from None


def available() -> List[str]:
    return sorted(_REGISTRY)
