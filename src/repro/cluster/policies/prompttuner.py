"""PromptTuner Workload Scheduler (§4.4) — Algorithms 1 & 2 as a policy.

Two-tier GPU pools: a single shared *cold* pool (free until claimed) and
per-LLM *warm* pools (pre-loaded runtime + weights; billed). Each round:

  1. **Algorithm 1** (warm allocation): sort pending jobs by service-
     class priority, then SLO ascending (single-class traces reduce to
     pure EDF); grow each job's allocation ``A_i`` until the predicted
     completion ``T_warm(A_i)`` fits the remaining SLO, then claim idle
     warm GPUs and start.
  2. **Algorithm 2** (cold allocation): for jobs Algorithm 1 could not
     satisfy, first try ``DelaySchedulable`` — can the job still meet its
     SLO by waiting for GPUs that running jobs will release (earliest-
     release list ``E_l``, taken from the engine's actual completion
     events)? Only if not, grow the warm pool from the cold pool, paying
     ``T_cold``.
  3. Reclaim warm GPUs idle for >= 60 s back to the cold pool (the
     default ``maintain`` hook).

The latency budget (§4.4.3) routes a job through the Prompt Bank only if
the bank's lookup latency fits in 20 % of the job's SLO.

Best-effort backstop (not in the paper's pseudocode, required for a
complete system): jobs whose SLO is already infeasible still execute with
one replica when warm GPUs would otherwise sit idle — users still get
their prompt back; the job simply counts as an SLO violation.
"""
from __future__ import annotations

from typing import Dict, List

from repro.cluster.engine import ResourceView
from repro.cluster.policies.base import (
    SchedulingPolicy,
    admission_key,
    min_replicas_for_slo,
    register,
)
from repro.core.jobs import Job, exec_time


@register
class PromptTunerPolicy(SchedulingPolicy):
    """The full PromptTuner system as a pluggable policy."""

    name = "prompttuner"

    # -- prediction -------------------------------------------------------------

    def _t_warm(self, job: Job, replicas: int, used_bank: bool) -> float:
        """T_i^warm(a): upper-bound completion estimate from a warm pool
        (§4.4: max remaining iterations x per-iteration time + warm
        allocation overhead [+ bank lookup])."""
        prof = job.profile()
        return exec_time(
            job,
            replicas * prof.gpus_per_replica,
            used_bank=used_bank,
            alloc_overhead=prof.warm_overhead,
        )

    # -- Algorithm 1: GPU allocation from a warm pool ------------------------------

    def _alg1_warm(self, view: ResourceView) -> List[Job]:
        """Allocate idle warm GPUs to pending jobs (SLO-ascending).
        Returns the jobs that could NOT be satisfied from warm pools."""
        unsatisfied: List[Job] = []
        for llm, queue in view.pending.items():
            if not queue:
                continue
            pool = view.pool(llm)
            prof = queue[0].profile()
            queue.sort(key=admission_key)
            leftover: List[Job] = []
            for job in queue:
                used_bank = view.use_bank_for(job)
                slo_rem = view.slo_remaining(job)
                r_l = len(pool.idle) // prof.gpus_per_replica
                a = 1
                while (self._t_warm(job, a, used_bank) > slo_rem
                       and a <= min(r_l, self.cfg.max_replicas_per_job) - 1):
                    a += 1
                feasible = (a <= r_l
                            and self._t_warm(job, a, used_bank) <= slo_rem)
                if feasible and self.cfg.use_warm:
                    took = pool.take_idle(a * prof.gpus_per_replica)
                    assert took == a * prof.gpus_per_replica
                    # Table 8 'w/o Warm Allocator': per-instance sequential
                    # connects instead of one simultaneous gang allocation
                    if self.cfg.use_warm_allocator:
                        overhead = prof.warm_overhead
                    else:
                        overhead = prof.warm_overhead * took
                    view.start_job(job, took, overhead, used_bank)
                else:
                    leftover.append(job)
                    unsatisfied.append(job)
            view.pending[llm] = leftover
        return unsatisfied

    # -- Algorithm 2: GPU allocation from the cold pool ------------------------------

    def _delay_schedulable(self, view: ResourceView, E_l: List[float],
                           job: Job) -> bool:
        """DelaySchedulable (Alg 2 lines 23-35): True if waiting for
        soon-to-be-released warm GPUs still meets the SLO. Mutates E_l to
        mark the claimed GPUs (so later jobs in this round see them as
        taken)."""
        if not self.cfg.use_delay:
            return False
        prof = job.profile()
        used_bank = view.use_bank_for(job)
        n = len(E_l)
        k = 1
        while k <= n // prof.gpus_per_replica:
            g = k * prof.gpus_per_replica
            avail_at = E_l[g - 1]            # k replicas available then
            finish = avail_at + self._t_warm(job, k, used_bank)
            if finish <= job.deadline:
                # claim: those GPUs release only after this job finishes
                for i in range(g):
                    E_l[i] = finish
                E_l.sort()
                return True
            k += 1
        return False

    def _alg2_cold(self, view: ResourceView, unsatisfied: List[Job]) -> None:
        """Grow warm pools from the cold pool for jobs that cannot be
        delayed (SLO-ascending)."""
        timelines: Dict[str, List[float]] = {}
        unsatisfied.sort(key=admission_key)
        for job in unsatisfied:
            llm = job.llm
            prof = job.profile()
            E_l = timelines.setdefault(llm, view.release_timeline(llm))
            if self._delay_schedulable(view, E_l, job):
                continue
            used_bank = view.use_bank_for(job)
            slo_rem = view.slo_remaining(job)
            t_cold = prof.cold_overhead
            max_rep = min(view.cold_free // prof.gpus_per_replica,
                          self.cfg.max_replicas_per_job)
            if max_rep < 1:
                continue
            a, feasible = min_replicas_for_slo(
                job, used_bank=used_bank, slo_rem=slo_rem, max_rep=max_rep,
                overhead=t_cold)
            if feasible:
                g = a * prof.gpus_per_replica
                view.warm_up(llm, g, t_cold)
                # the job stays pending; Algorithm 1 starts it once the
                # warm-up matures. Mark claims on the timeline.
                ready = view.now + t_cold
                finish = ready + self._t_warm(job, a, used_bank)
                E_l.extend([finish] * g)
                E_l.sort()

    # -- best-effort backstop ----------------------------------------------------------

    def _best_effort(self, view: ResourceView) -> None:
        if not self.cfg.best_effort:
            return
        for llm, queue in view.pending.items():
            if not queue:
                continue
            pool = view.pool(llm)
            prof = queue[0].profile()
            leftover: List[Job] = []
            for job in sorted(queue, key=admission_key):
                g = prof.gpus_per_replica
                # run hopeless jobs on idle warm GPUs (lowest priority)
                hopeless = (self._t_warm(job, self.cfg.max_replicas_per_job,
                                         False) > view.slo_remaining(job))
                if hopeless and len(pool.idle) >= g:
                    pool.take_idle(g)
                    view.start_job(job, g, prof.warm_overhead,
                                   view.use_bank_for(job))
                elif hopeless and view.cold_free >= g and not pool.warming:
                    # bring up minimal capacity for a starved LLM
                    view.warm_up(llm, g, prof.cold_overhead)
                    leftover.append(job)
                else:
                    leftover.append(job)
            view.pending[llm] = leftover

    # -- round ---------------------------------------------------------------------------

    def on_round(self, view: ResourceView) -> None:
        if not self.cfg.use_warm:
            # runtime-reuse ablation: every allocation is a cold start and
            # GPUs return to cold immediately on completion
            self._round_no_warm(view)
            return
        unsatisfied = self._alg1_warm(view)
        self._alg2_cold(view, unsatisfied)
        self._best_effort(view)

    # -- ablation: no runtime reusing (Fig 8a/b 'w/o R.R.') ---------------------------------

    def _round_no_warm(self, view: ResourceView) -> None:
        for llm, queue in view.pending.items():
            if not queue:
                continue
            prof = queue[0].profile()
            queue.sort(key=admission_key)
            leftover: List[Job] = []
            for job in queue:
                used_bank = view.use_bank_for(job)
                slo_rem = view.slo_remaining(job)
                max_rep = min(view.cold_free // prof.gpus_per_replica,
                              self.cfg.max_replicas_per_job)
                if max_rep < 1:
                    leftover.append(job)
                    continue
                a, feasible = min_replicas_for_slo(
                    job, used_bank=used_bank, slo_rem=slo_rem,
                    max_rep=max_rep, overhead=prof.cold_overhead)
                g = a * prof.gpus_per_replica
                if feasible or self.cfg.best_effort:
                    view.claim_cold_busy(llm, g)
                    view.start_job(job, g, prof.cold_overhead, used_bank)
                else:
                    leftover.append(job)
            view.pending[llm] = leftover

    def on_job_done(self, job: Job, gpus: int, view: ResourceView) -> None:
        if self.cfg.use_warm:
            view.release(job.llm, gpus)
        else:
            view.return_cold(job.llm, gpus)
