"""INFless [85] (§3, §6.1) — SLO-aware serverless DL *inference* system,
reinforced per the paper with (a) multi-GPU execution over a Memcached
channel and (b) the Prompt Bank, for a fair comparison. Characteristics
modeled:

  * per-model instance autoscaling with a keep-alive window (billed while
    alive, busy or idle),
  * one GPU per instance; a multi-GPU job starts only when ALL of its
    instances are up — warm instances connect in ~2 s but each cold
    instance pays the full container/runtime/weights bring-up, so the job
    start time is the MAX over instance inits (the straggler effect of
    Fig 3b, 11-50 % of end-to-end latency),
  * no global schedule: per-model FIFO, no delayed execution.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.cluster.engine import ResourceView, SimConfig
from repro.cluster.policies.base import SchedulingPolicy, register
from repro.core.jobs import Job, exec_time


@register
class INFlessPolicy(SchedulingPolicy):
    name = "infless"

    # Serverless keep-alive is tuned for single-GPU inference traffic;
    # multi-instance LPT jobs release whole gangs at once, so the idle
    # tail INFless pays for is ~2x the per-model window PromptTuner's
    # demand-driven reclaim holds (its scheduler returns GPUs as soon as
    # the warm pool exceeds pending demand).
    KEEP_ALIVE_FACTOR = 2.0
    # container bring-up is heavy-tailed (Fig 3b: init is 11 % of e2e
    # latency on average, up to 50 %): each cold instance draws its init
    # time from cold_overhead x U(0.8, 2.2); a multi-instance gang waits
    # for the slowest (the straggler the warm allocator avoids).
    INIT_JITTER = (0.8, 2.2)

    def __init__(self, cfg: SimConfig):
        super().__init__(cfg)
        self._rng = np.random.default_rng(12345)

    def maintain(self, view: ResourceView) -> None:
        # keep-alive: idle instances die after the window
        view.mature_and_reclaim(self.cfg.keep_alive * self.KEEP_ALIVE_FACTOR)

    def on_round(self, view: ResourceView) -> None:
        for llm, queue in view.pending.items():
            if not queue:
                continue
            pool = view.pool(llm)
            prof = queue[0].profile()
            queue.sort(key=lambda j: j.submit_time)      # FIFO, no global sort
            leftover: List[Job] = []
            for job in queue:
                used_bank = view.use_bank_for(job)
                slo_rem = view.slo_remaining(job)
                avail = len(pool.idle) + view.cold_free
                max_rep = min(avail // prof.gpus_per_replica,
                              self.cfg.max_replicas_per_job)
                if max_rep < 1:
                    leftover.append(job)
                    continue
                # grow instances until the SLO fits. INFless is SLO-aware
                # about startup: it uses the cold bring-up estimate once
                # the allocation exceeds the warm instances. The remaining
                # inefficiency (the paper's #2) is the STRAGGLER: one cold
                # instance delays the whole multi-instance gang.
                a = 1
                while a < max_rep:
                    g = a * prof.gpus_per_replica
                    oh = (prof.warm_overhead if g <= len(pool.idle)
                          else prof.cold_overhead)
                    if exec_time(job, g, used_bank=used_bank,
                                 alloc_overhead=oh) <= slo_rem:
                        break
                    a += 1
                g = a * prof.gpus_per_replica
                n_warm = min(len(pool.idle), g)
                n_cold = g - n_warm
                pool.take_idle(n_warm)
                if n_cold:
                    view.claim_cold_busy(llm, n_cold)
                # straggler: the job waits for the SLOWEST instance init
                if n_cold:
                    jitter = self._rng.uniform(*self.INIT_JITTER,
                                               size=n_cold).max()
                    overhead = prof.cold_overhead * float(jitter)
                else:
                    overhead = prof.warm_overhead
                view.start_job(job, g, overhead, used_bank)
            view.pending[llm] = leftover
