"""Pure discrete-event cluster engine for LPT scheduling (§4.4, §6).

This module is the **mechanism** half of the policy/mechanism split:

* :class:`ClusterEngine` advances an event heap (arrivals / scheduler
  rounds / job completions) one :meth:`~ClusterEngine.step` at a time
  and accrues resource cost continuously as ``billed_gpus * dt * price``
  — globally and per tenant. It owns the pending queues, the per-LLM
  warm pools, the shared cold pool, and the billing and record-keeping —
  and contains **no system-specific scheduling logic**. Each processed
  event is also published to ``on_event`` subscribers as a typed
  :class:`EngineEvent` (service-level streaming).
* :class:`ResourceView` is the narrow API a
  :class:`~repro.cluster.policies.SchedulingPolicy` sees each round:
  pending queues, warm pools, cold capacity, release timelines, and the
  ``start_job`` / ``warm_up`` / ``reclaim`` verbs. The view enforces the
  resource invariants (cold pool never negative, warm-pool accounting
  conserved) so a buggy policy fails loudly instead of corrupting state.

Systems (PromptTuner, INFless, ElasticFlow, ...) live in
``repro.cluster.policies`` and are obtained via the string-keyed
registry::

    from repro.cluster import policies
    engine = policies.build("prompttuner", SimConfig(max_gpus=32))
    result = engine.run(jobs)

Execution model (calibrated by §2.2's characterization):
    finish = start + alloc_overhead [+ bank_lookup] + iters * iter_time(g)
with near-linear scaling ``iter_time(g)`` from ``repro.core.jobs`` (comm
is 0.4-0.5 % per extra replica — Fig 2a). Allocation is non-preemptive:
the GPU count is fixed at job start, matching Algorithms 1/2 which decide
allocations for *pending* jobs only. Scheduler rounds fire every
``round_interval`` seconds (paper §5.3: 50 ms rounds; the default here is
coarser purely to keep event counts small — results are insensitive below
~1 s because job durations are seconds-to-minutes).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import (
    Any,
    Callable,
    Dict,
    Iterable,
    List,
    Optional,
    Sequence,
    Tuple,
)

from repro.core.jobs import (
    GPU_PRICE_PER_S,
    STORAGE_PRICE_PER_JOB_S,
    Job,
    JobPhase,
    SLOClass,
    exec_time,
    iter_time,
)

ARRIVAL, ROUND, JOB_DONE = "arrival", "round", "job_done"

# Ledger key for provisioned-but-not-busy capacity (idle / warming warm
# GPUs): billed globally, attributable to no single tenant.
SHARED_POOL = "(shared-pool)"


@dataclass(frozen=True)
class EngineEvent:
    """One observable engine transition, delivered to ``on_event``
    subscribers in simulated-time order.

    ``kind`` is one of :data:`ARRIVAL` (a job entered the pending
    queues), :data:`ROUND` (a scheduler round ran), :data:`JOB_DONE`
    (a job completed — exactly one per completed job). ``shard`` is 0
    for a bare engine; :class:`~repro.cluster.fabric.ClusterFabric`
    rewrites it to the originating shard index when forwarding. The
    elastic control plane (:mod:`repro.cluster.elastic`) additionally
    emits fabric-level kinds (job stolen / shard resized / job
    rejected), using ``detail`` for the human-readable specifics.
    """

    kind: str
    time: float
    job: Optional[Job] = None
    shard: int = 0
    detail: Optional[str] = None


def bank_fits_budget(cfg: "SimConfig", bank_lookup_s: float,
                     slo: float) -> bool:
    """§4.4.3 latency budget: route through the Prompt Bank only if its
    lookup latency fits within ``latency_budget_frac`` of the SLO. The
    single implementation shared by the engine and the service facade."""
    if not cfg.use_bank:
        return False
    if not cfg.use_latency_budget:
        return True                    # Table 8: bank for EVERY request
    return bank_lookup_s <= cfg.latency_budget_frac * slo


@dataclass
class SimConfig:
    max_gpus: int = 32                 # cold-pool size / cluster size
    round_interval: float = 0.5        # scheduler round period (s)
    reclaim_window: float = 60.0       # idle warm GPU -> cold after this (s)
    keep_alive: float = 60.0           # INFless instance keep-alive (s)
    price_per_gpu_s: float = GPU_PRICE_PER_S
    latency_budget_frac: float = 0.2   # §4.4.3
    use_bank: bool = True              # prompt reusing on/off (Fig 8a/b)
    use_warm: bool = True              # runtime reusing on/off
    use_warm_allocator: bool = True    # simultaneous multi-GPU alloc (Table 8)
    use_delay: bool = True             # DelaySchedulable on/off (Table 8)
    use_latency_budget: bool = True    # Table 8 'w/o Latency Budget'
    max_replicas_per_job: int = 16
    best_effort: bool = True           # run SLO-infeasible jobs when idle
    # Crash-aware checkpointing (None = off: durations are bit-identical
    # to a checkpoint-free engine, which is what the goldens pin). With
    # an interval, every `checkpoint_interval_s` of tuning compute pays
    # one `checkpoint_write_s`; a job resuming from checkpointed
    # progress (iters_done > 0) pays `checkpoint_restore_s` once.
    checkpoint_interval_s: Optional[float] = None
    checkpoint_write_s: float = 1.5
    checkpoint_restore_s: float = 4.0
    # Jobs whose remaining tuning compute is below this never checkpoint
    # (no writes, no crash credit): the write tax is paid by every job
    # up front while the credit only pays out for the few that actually
    # die mid-flight, so snapshotting short jobs is negative expected
    # value. 0.0 (default) checkpoints everything.
    checkpoint_min_compute_s: float = 0.0


@dataclass
class JobRecord:
    job: Job
    gpus: int
    used_bank: bool
    start: float
    finish: float
    violated: bool
    wait: float                        # queueing delay
    init_overhead: float               # allocation / instance-init share


@dataclass
class SimResult:
    records: List[JobRecord]
    cost: float
    gpu_seconds: float
    makespan: float
    util_samples: List[Tuple[float, float]] = field(default_factory=list)
    cost_by_tenant: Dict[str, float] = field(default_factory=dict)
    gpu_seconds_by_tenant: Dict[str, float] = field(default_factory=dict)

    @property
    def slo_violation(self) -> float:
        if not self.records:
            return 0.0
        return sum(r.violated for r in self.records) / len(self.records)

    def summary(self) -> Dict[str, float]:
        return {
            "jobs": len(self.records),
            "slo_violation_pct": 100.0 * self.slo_violation,
            "cost_usd": self.cost,
            "gpu_seconds": self.gpu_seconds,
            "makespan_s": self.makespan,
        }

    def summary_by_tenant(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant SLO/billing breakdown: the tenant's own jobs and
        violations plus its share of the cost/GPU-second ledgers (busy
        time at the tenant's price tier; the :data:`SHARED_POOL` row
        carries idle/warming capacity attributable to no tenant)."""
        per: Dict[str, List[JobRecord]] = {}
        for r in self.records:
            per.setdefault(r.job.tenant, []).append(r)
        tenants = set(per) | set(self.cost_by_tenant) | set(
            self.gpu_seconds_by_tenant)
        out: Dict[str, Dict[str, float]] = {}
        for t in sorted(tenants):
            recs = per.get(t, [])
            out[t] = {
                "jobs": len(recs),
                "slo_violation_pct": (
                    100.0 * sum(r.violated for r in recs) / len(recs)
                    if recs else 0.0),
                "cost_usd": self.cost_by_tenant.get(t, 0.0),
                "gpu_seconds": self.gpu_seconds_by_tenant.get(t, 0.0),
            }
        return out


class WarmPool:
    """Per-LLM warm GPU pool: idle (with idle-since), warming (ready-at),
    and busy counts. All GPUs in the pool are billed."""

    def __init__(self) -> None:
        self.idle: List[float] = []        # idle_since per idle GPU
        self.warming: List[float] = []     # ready_at (heap)
        self.busy: int = 0

    def total(self) -> int:
        return len(self.idle) + len(self.warming) + self.busy

    def take_idle(self, n: int) -> int:
        """Claim up to n idle GPUs; returns how many were claimed."""
        n = min(n, len(self.idle))
        # take the most recently idle ones (LIFO keeps cold candidates old)
        for _ in range(n):
            self.idle.pop()
        self.busy += n
        return n

    def release(self, n: int, now: float) -> None:
        self.busy -= n
        assert self.busy >= 0
        self.idle.extend([now] * n)

    def mature(self, now: float) -> None:
        """Move warming GPUs whose ready_at has passed into idle."""
        ready = [t for t in self.warming if t <= now + 1e-9]
        self.warming = [t for t in self.warming if t > now + 1e-9]
        self.idle.extend([now] * len(ready))

    def reclaim(self, now: float, window: float) -> int:
        """Return idle GPUs unused for `window` seconds to the cold pool."""
        keep = [t for t in self.idle if now - t < window]
        n = len(self.idle) - len(keep)
        self.idle = keep
        return n


class ResourceView:
    """The resource API a scheduling policy acts through.

    Read surface: ``now`` / ``cfg`` / ``cold_free`` / ``pending`` /
    ``pool`` / ``running`` / ``release_timeline`` / ``slo_remaining`` /
    ``slo_class_of`` / ``tenant_of`` / ``tenants`` / ``use_bank_for``.
    Write verbs: ``start_job``, ``warm_up``,
    ``claim_cold_busy``, ``return_cold``, ``release``,
    ``mature_and_reclaim``. The verbs assert the engine's resource
    invariants (cold pool non-negative, warm-pool counts conserved).
    """

    def __init__(self, engine: "ClusterEngine") -> None:
        self._e = engine

    # -- read surface --------------------------------------------------------

    @property
    def now(self) -> float:
        return self._e.now

    @property
    def cfg(self) -> SimConfig:
        return self._e.cfg

    @property
    def cold_free(self) -> int:
        return self._e.cold_free

    @property
    def pending(self) -> Dict[str, List[Job]]:
        """Live per-LLM pending queues. Policies admit a job by removing
        it from its queue and calling :meth:`start_job` (or by replacing
        the queue wholesale: ``view.pending[llm] = leftover``)."""
        return self._e.pending

    def pool(self, llm: str) -> WarmPool:
        return self._e.pool(llm)

    def pools(self) -> Dict[str, WarmPool]:
        return self._e.pools

    def running(self) -> Iterable[Tuple[Job, int]]:
        return self._e.running.values()

    def total_warm(self) -> int:
        return sum(p.total() for p in self._e.pools.values())

    def release_timeline(self, llm: str) -> List[float]:
        """E_l (§4.4 Algorithm 2): earliest timestamps at which each warm
        GPU of LLM ``llm`` becomes available — idle now, warming, or
        released by running jobs at their **actual scheduled completion
        events** (not a recomputed estimate, which can drift when the
        start paid a different allocation overhead)."""
        return self._e.release_timeline(llm)

    def slo_remaining(self, job: Job) -> float:
        return job.deadline - self._e.now

    def slo_class_of(self, job: Job) -> SLOClass:
        """The job's service class (priority / price tier / stringency) —
        the hook class-aware policies order admission by."""
        return job.slo_class

    def tenant_of(self, job: Job) -> str:
        return job.tenant

    def tenants(self) -> List[str]:
        """Tenants with work currently pending or running, sorted."""
        names = {j.tenant for q in self._e.pending.values() for j in q}
        names.update(j.tenant for j, _ in self._e.running.values())
        return sorted(names)

    def use_bank_for(self, job: Job) -> bool:
        return self._e.use_bank_for(job)

    def tenant_gpu_seconds(self, tenant: str) -> float:
        """Completed-work GPU-second ledger for ``tenant`` on this shard
        — the quota read a budget-aware policy orders admission by.
        (Fleet-wide enforcement, including in-flight commitments, lives
        in :class:`~repro.cluster.elastic.ElasticController`.)"""
        return self._e.gpu_seconds_by_tenant.get(tenant, 0.0)

    def tenant_cost(self, tenant: str) -> float:
        """Completed-work billed cost for ``tenant`` on this shard (at
        the tenant's class price tier)."""
        return self._e.cost_by_tenant.get(tenant, 0.0)

    # -- write verbs ---------------------------------------------------------

    def start_job(self, job: Job, gpus: int, alloc_overhead: float,
                  used_bank: bool) -> None:
        """Commit a job to run on ``gpus`` GPUs starting now. The caller
        must already have claimed the GPUs (warm ``take_idle`` or a cold
        verb); the engine schedules the completion event and bills."""
        self._e.start_job(job, gpus, alloc_overhead, used_bank)

    def warm_up(self, llm: str, n: int, ready_in: float) -> None:
        """Grow ``llm``'s warm pool by ``n`` GPUs from the cold pool; they
        become idle (schedulable) after ``ready_in`` seconds."""
        if n > self._e.cold_free:
            raise ValueError(
                f"warm_up({llm}, {n}): only {self._e.cold_free} cold GPUs free")
        self._e.cold_free -= n
        self._e.pool(llm).warming.extend([self._e.now + ready_in] * n)

    def claim_cold_busy(self, llm: str, n: int) -> None:
        """Take ``n`` cold GPUs straight into ``llm``'s busy count (a cold
        start that skips the warming state; the job pays the cold
        overhead in its own execution time)."""
        if n > self._e.cold_free:
            raise ValueError(
                f"claim_cold_busy({llm}, {n}): only {self._e.cold_free} free")
        self._e.cold_free -= n
        self._e.pool(llm).busy += n

    def return_cold(self, llm: str, n: int) -> None:
        """Return ``n`` busy GPUs of ``llm`` directly to the cold pool
        (no warm reuse)."""
        p = self._e.pool(llm)
        if n > p.busy:
            raise ValueError(f"return_cold({llm}, {n}): only {p.busy} busy")
        p.busy -= n
        self._e.cold_free += n

    def release(self, llm: str, n: int) -> None:
        """Release ``n`` busy GPUs of ``llm`` into its warm-idle set."""
        self._e.pool(llm).release(n, self._e.now)

    def mature_and_reclaim(self, window: float) -> int:
        """Round upkeep: mature warming GPUs and reclaim those idle for
        >= ``window`` seconds back to the cold pool. Returns the number
        reclaimed."""
        total = 0
        for p in self._e.pools.values():
            p.mature(self._e.now)
            total += p.reclaim(self._e.now, window)
        self._e.cold_free += total
        return total


class ClusterEngine:
    """Event-driven cluster mechanism, driven by a pluggable policy.

    ``ClusterEngine(cfg, policy)`` is the canonical form. For backwards
    compatibility the engine can also be subclassed with ``_schedule``
    overridden (the pre-registry ``ClusterSim`` contract); the legacy
    hooks delegate to the policy when one is attached.
    """

    name = "base"

    def __init__(self, cfg: SimConfig, policy: Optional[Any] = None):
        self.cfg = cfg
        self.policy = policy
        if policy is not None and getattr(policy, "name", None):
            self.name = policy.name
        self.view = ResourceView(self)
        self.now = 0.0
        self._seq = itertools.count()
        self._events: List[Tuple[float, int, str, Any]] = []
        self.pending: Dict[str, List[Job]] = {}
        self.running: Dict[int, Tuple[Job, int]] = {}    # job_id -> (job, gpus)
        self._finish_at: Dict[int, float] = {}           # job_id -> scheduled done
        self.records: List[JobRecord] = []
        self.cost = 0.0
        self.gpu_seconds = 0.0
        self.cost_by_tenant: Dict[str, float] = {}
        self.gpu_seconds_by_tenant: Dict[str, float] = {}
        self.cold_free = cfg.max_gpus
        self.pools: Dict[str, WarmPool] = {}
        self.util_samples: List[Tuple[float, float]] = []
        self.outstanding_jobs = 0      # submitted, not yet recorded
        self._subscribers: List[Callable[[EngineEvent], None]] = []
        self._rounds_armed = 0         # ROUND events currently queued
        # fault-plane state: step-time multiplier (straggler) and the
        # per-running-job info needed to credit checkpoints at a crash
        self.speed = 1.0
        self._run_info: Dict[int, Dict[str, float]] = {}

    # -- event stream ---------------------------------------------------------

    def on_event(self, cb: Callable[[EngineEvent], None]) -> None:
        """Subscribe ``cb`` to the engine's event stream. It is called
        synchronously, in simulated-time order, with one
        :class:`EngineEvent` per ARRIVAL / ROUND / JOB_DONE transition
        (exactly one JOB_DONE per completed job)."""
        self._subscribers.append(cb)

    def _emit(self, kind: str, job: Optional[Job] = None) -> None:
        if not self._subscribers:
            return
        ev = EngineEvent(kind=kind, time=self.now, job=job)
        for cb in self._subscribers:
            cb(ev)

    # -- billing --------------------------------------------------------------

    def billed_gpus(self) -> int:
        """GPUs currently accruing cost. Default: all warm-pool GPUs."""
        if self.policy is not None:
            return self.policy.billed_gpus(self.view)
        return sum(p.total() for p in self.pools.values())

    def _advance(self, t: float) -> None:
        dt = t - self.now
        if dt > 0:
            g = self.billed_gpus()
            self.cost += g * dt * self.cfg.price_per_gpu_s
            self.gpu_seconds += g * dt
            self.now = t

    # -- event plumbing --------------------------------------------------------

    def _push(self, t: float, kind: str, payload: Any = None) -> None:
        heapq.heappush(self._events, (t, next(self._seq), kind, payload))

    def _push_round(self, t: float) -> None:
        self._rounds_armed += 1
        self._push(t, ROUND)

    def ensure_round(self, at: float) -> None:
        """Arm a scheduler round at ``at`` (clamped to now) if none is
        queued. Used by mid-run injection (:meth:`admit_at`): a drained
        engine's round chain has stopped, and without re-arming an
        injected job would sit in pending forever."""
        if self._rounds_armed == 0:
            self._push_round(max(at, self.now))

    def pool(self, llm: str) -> WarmPool:
        if llm not in self.pools:
            self.pools[llm] = WarmPool()
        return self.pools[llm]

    # -- job lifecycle ----------------------------------------------------------

    def use_bank_for(self, job: Job) -> bool:
        """§4.4.3 latency budget applied to one job."""
        return bank_fits_budget(self.cfg, job.profile().bank_lookup_s, job.slo)

    def release_timeline(self, llm: str) -> List[float]:
        """Earliest availability per warm GPU of ``llm``, ascending. Uses
        the actual JOB_DONE events the engine has scheduled for running
        jobs — the single source of truth for completion times."""
        pool = self.pool(llm)
        ts: List[float] = [self.now] * len(pool.idle)
        ts.extend(pool.warming)
        for job, gpus in self.running.values():
            if job.llm != llm:
                continue
            release = self._finish_at.get(job.job_id, self.now)
            ts.extend([max(release, self.now)] * gpus)
        return sorted(ts)

    def start_job(self, job: Job, gpus: int, alloc_overhead: float,
                  used_bank: bool) -> None:
        prof = job.profile()
        dur = exec_time(job, gpus, used_bank=used_bank,
                        alloc_overhead=alloc_overhead)
        overhead = alloc_overhead + (
            prof.bank_lookup_s if used_bank else 0.0
        )
        ckpt = self.cfg.checkpoint_interval_s
        ckpt_on = False
        if ckpt is not None:
            # crash-aware: restore once when resuming from checkpointed
            # progress, plus one write per completed checkpoint interval
            # of tuning compute (jobs too short to plausibly benefit are
            # exempt — see checkpoint_min_compute_s).
            if job.iters_done > 0:
                dur += self.cfg.checkpoint_restore_s
                overhead += self.cfg.checkpoint_restore_s
            compute_s = job.iters(used_bank) * iter_time(prof, gpus)
            ckpt_on = compute_s >= self.cfg.checkpoint_min_compute_s
            if ckpt_on:
                dur += int(compute_s // ckpt) * self.cfg.checkpoint_write_s
        if self.speed != 1.0:              # straggler multiplier
            dur *= self.speed
        job.phase = JobPhase.RUNNING
        job.start_time = self.now
        job.gpus = gpus
        job.used_bank = used_bank
        job.init_overhead = overhead
        self.running[job.job_id] = (job, gpus)
        self._finish_at[job.job_id] = self.now + dur
        self._run_info[job.job_id] = {
            "start": self.now,
            "iter_s": iter_time(prof, gpus),
            "used_bank": float(used_bank),
            "overhead_wall": overhead * self.speed,
            "speed": self.speed,
            "ckpt_on": float(ckpt_on),
        }
        self._push(self.now + dur, JOB_DONE, job)
        if gpus > prof.gpus_per_replica:   # multi-replica => storage channel
            self.cost += STORAGE_PRICE_PER_JOB_S * dur

    def _complete(self, job: Job) -> None:
        job.phase = JobPhase.DONE
        job.finish_time = self.now
        _, gpus = self.running.pop(job.job_id)
        self._finish_at.pop(job.job_id, None)
        self._run_info.pop(job.job_id, None)
        self.outstanding_jobs -= 1
        # Per-tenant ledger, alongside the global one. A job's GPU count
        # is fixed for its whole [start, finish] span, so the tenant's
        # busy share accrues once here (at the class price tier) instead
        # of taxing every _advance; result() derives the idle remainder
        # as the shared-pool row.
        dur = self.now - job.start_time
        if dur > 0:
            self.gpu_seconds_by_tenant[job.tenant] = (
                self.gpu_seconds_by_tenant.get(job.tenant, 0.0)
                + gpus * dur)
            self.cost_by_tenant[job.tenant] = (
                self.cost_by_tenant.get(job.tenant, 0.0)
                + gpus * dur * self.cfg.price_per_gpu_s
                * job.slo_class.price_tier)
        self._on_job_done(job, gpus)
        self.records.append(
            JobRecord(
                job=job,
                gpus=gpus,
                used_bank=job.used_bank,
                start=job.start_time,
                finish=self.now,
                violated=self.now > job.deadline + 1e-9,
                wait=job.start_time - job.submit_time,
                init_overhead=job.init_overhead,
            )
        )
        self._emit(JOB_DONE, job)

    # -- policy hooks (overridable by legacy subclasses) -------------------------

    def _on_job_done(self, job: Job, gpus: int) -> None:
        if self.policy is not None:
            self.policy.on_job_done(job, gpus, self.view)
        else:
            self.pool(job.llm).release(gpus, self.now)

    def _schedule(self) -> None:
        if self.policy is None:
            raise NotImplementedError("attach a SchedulingPolicy or "
                                      "override _schedule")
        self.policy.on_round(self.view)

    def _maintain(self) -> None:
        """Round upkeep: mature warming GPUs, reclaim idle ones."""
        if self.policy is not None:
            self.policy.maintain(self.view)
        else:
            self.view.mature_and_reclaim(self.cfg.reclaim_window)

    # -- main loop --------------------------------------------------------------------

    def submit(self, job: Job) -> None:
        """Enqueue an arrival (at its submit_time, or now if in the past).
        Takes effect on the next :meth:`run` / :meth:`step` cycle."""
        self.outstanding_jobs += 1
        self._push(max(job.submit_time, self.now), ARRIVAL, job)

    # -- elastic-mechanism verbs (used by the fabric control plane) ------------

    def admit_at(self, job: Job, at: float) -> None:
        """Inject ``job`` mid-run with an arrival at ``max(at, now)`` —
        the work-stealing requeue path. Unlike :meth:`submit` this also
        re-arms the scheduler-round chain: a drained engine would
        otherwise never look at its pending queue again."""
        self.outstanding_jobs += 1
        t = max(at, self.now)
        self._push(t, ARRIVAL, job)
        self.ensure_round(t)

    def extract_pending(self, job_id: int) -> Optional[Job]:
        """Remove and return a still-pending job (the donor half of a
        steal); ``None`` if the job is not pending here — already
        running, done, or still an undelivered arrival event."""
        for llm, queue in self.pending.items():
            for k, j in enumerate(queue):
                if j.job_id == job_id:
                    queue.pop(k)
                    self.outstanding_jobs -= 1
                    return j
        return None

    def cancel_running(self, job_id: int, at: float
                       ) -> Optional[Tuple[Job, int]]:
        """Kill a running job mid-flight (the graceful-degradation shed
        path): its GPUs release back to the warm pool immediately, the
        partial run is billed to its tenant, and the already-scheduled
        JOB_DONE event is lazily invalidated (:meth:`step` skips
        completions for jobs no longer running). The caller owns the
        terminal outcome — no JobRecord is appended here. Returns
        ``(job, gpus)``, or None if the job is not running."""
        if job_id not in self.running:
            return None
        t = max(at, self.now)
        self._advance(t)
        job, gpus = self.running.pop(job_id)
        self._finish_at.pop(job_id, None)
        self._run_info.pop(job_id, None)
        self.outstanding_jobs -= 1
        dur = t - job.start_time
        if dur > 0:
            self.gpu_seconds_by_tenant[job.tenant] = (
                self.gpu_seconds_by_tenant.get(job.tenant, 0.0)
                + gpus * dur)
            self.cost_by_tenant[job.tenant] = (
                self.cost_by_tenant.get(job.tenant, 0.0)
                + gpus * dur * self.cfg.price_per_gpu_s
                * job.slo_class.price_tier)
        self._on_job_done(job, gpus)
        return job, gpus

    def pending_jobs(self) -> List[Job]:
        """Every job currently in a pending queue (all LLMs)."""
        return [j for q in self.pending.values() for j in q]

    def queued_arrivals(self) -> List[Job]:
        """Jobs submitted but whose arrival event has not fired yet."""
        return [p for _, _, k, p in self._events if k == ARRIVAL]

    def finish_time_of(self, job_id: int) -> Optional[float]:
        """The scheduled completion time of a running job (None if the
        job is not running)."""
        return self._finish_at.get(job_id)

    def resize(self, new_max_gpus: int) -> int:
        """Grow or shrink this engine's fleet slice between scheduling
        rounds. Growth adds cold (free, unbilled) GPUs; shrinkage can
        only take cold GPUs — warm and busy capacity is never revoked,
        so ledgers and running jobs are untouched. Returns the actual
        new capacity (a shrink is clamped to the free cold pool). A
        negative target is a caller bug, rejected loudly."""
        if new_max_gpus < 0:
            raise ValueError(
                f"resize target must be >= 0 GPUs, got {new_max_gpus}")
        delta = new_max_gpus - self.cfg.max_gpus
        if delta >= 0:
            self.cold_free += delta
        else:
            take = min(-delta, self.cold_free)
            self.cold_free -= take
            delta = -take
        self.cfg.max_gpus += delta
        return self.cfg.max_gpus

    # -- fault-plane verbs (used by repro.cluster.faults) ----------------------

    def _credit_checkpoint(self, job: Job, t: float, *,
                           final: bool = False) -> None:
        """Credit a killed job with the iterations its last completed
        checkpoint covers. Progress advances in whole checkpoint blocks:
        one block = ``checkpoint_interval_s`` of compute plus one write,
        both stretched by the shard's speed multiplier at start time.
        ``final=True`` models a snapshot flushed during a preemption
        warning lead: every completed iteration survives, not just the
        last periodic block."""
        info = self._run_info.get(job.job_id)
        ckpt = self.cfg.checkpoint_interval_s
        if info is None or ckpt is None or info["iter_s"] <= 0:
            return
        if not info.get("ckpt_on", 1.0):
            return
        block_wall = (ckpt + self.cfg.checkpoint_write_s) * info["speed"]
        work = t - info["start"] - info["overhead_wall"]
        if work <= 0 or block_wall <= 0:
            return
        if final:
            stalls = int(work // block_wall) * (
                self.cfg.checkpoint_write_s * info["speed"])
            compute = (work - stalls) / info["speed"]
            credit = int(compute / info["iter_s"])
        else:
            credit = int(int(work // block_wall) * ckpt / info["iter_s"])
        remaining = job.iters(bool(info["used_bank"]))
        job.iters_done += min(credit, remaining)

    def crash(self, at: float, *, final_snapshot: bool = False
              ) -> Tuple[List[Job], int]:
        """Fail this shard at ``at``: billing advances to the crash
        instant, every running job is killed (checkpointed progress
        credited onto ``job.iters_done``), pending jobs and undelivered
        arrivals are orphaned, all pools are dropped, and capacity goes
        to zero (a dead shard neither bills nor attracts placement).
        ``final_snapshot=True`` means the kill was announced (spot
        preemption warning) and the lead time flushed a last checkpoint,
        so running jobs keep all completed iterations. Returns
        ``(orphans, capacity_lost)``; the orphans still carry their
        runtime state so the fabric can emit lifecycle events before
        scrubbing them for requeue."""
        t = max(at, self.now)
        self._advance(t)
        orphans: List[Job] = []
        for job, _gpus in self.running.values():
            self._credit_checkpoint(job, t, final=final_snapshot)
            orphans.append(job)
        self.running.clear()
        self._finish_at.clear()
        self._run_info.clear()
        for q in self.pending.values():
            orphans.extend(q)
        self.pending.clear()
        orphans.extend(self.queued_arrivals())
        self._events.clear()
        self._rounds_armed = 0
        self.outstanding_jobs -= len(orphans)
        for p in self.pools.values():
            p.idle.clear()
            p.warming.clear()
            p.busy = 0
        lost = self.cfg.max_gpus
        self.cfg.max_gpus = 0
        self.cold_free = 0
        self.speed = 1.0
        return orphans, lost

    def restore(self, capacity: int, at: float) -> None:
        """Bring a crashed/preempted shard back with ``capacity`` cold
        GPUs at ``at``. Work re-enters via :meth:`admit_at`."""
        self._advance(max(at, self.now))
        self.cfg.max_gpus += capacity
        self.cold_free += capacity

    def set_speed(self, factor: float, at: float) -> None:
        """Apply a straggler step-time multiplier (> 1 is slower) to
        jobs started from ``at`` on. Already-running jobs keep their
        scheduled completions — the slowdown models degraded instances
        picking up new work, deterministically."""
        if factor <= 0:
            raise ValueError(f"speed factor must be > 0, got {factor}")
        self._advance(max(at, self.now))
        self.speed = factor

    def begin(self, jobs: Sequence[Job] = ()) -> None:
        """Submit ``jobs`` and arm the scheduler-round clock. Follow with
        :meth:`step` until it returns False, then :meth:`finish`."""
        for j in jobs:
            self.submit(j)
        self._push_round(self.now)

    def has_events(self) -> bool:
        return bool(self._events)

    def next_event_time(self) -> Optional[float]:
        """Timestamp of the next queued event (None when drained). Lets a
        fabric interleave several shards in global time order."""
        return self._events[0][0] if self._events else None

    def step(self) -> bool:
        """Process exactly one event (advance time, dispatch, notify
        subscribers). Returns False when the event heap is empty."""
        if not self._events:
            return False
        t, _, kind, payload = heapq.heappop(self._events)
        self._advance(t)
        if kind == ARRIVAL:
            if payload.profile().gpus_per_replica > self.cfg.max_gpus:
                # physically unschedulable on this fleet: no policy can
                # ever place it — record the violation immediately
                # instead of spinning rounds to the 24 h horizon
                self.records.append(
                    JobRecord(job=payload, gpus=0, used_bank=False,
                              start=float("inf"), finish=float("inf"),
                              violated=True, wait=float("inf"),
                              init_overhead=0.0)
                )
                self.outstanding_jobs -= 1
            else:
                self.pending.setdefault(payload.llm, []).append(payload)
            self._emit(ARRIVAL, payload)
        elif kind == JOB_DONE:
            # lazy invalidation: cancel_running leaves its stale
            # completion event in the heap
            if payload.job_id in self.running:
                self._complete(payload)
        elif kind == ROUND:
            self._rounds_armed -= 1
            self._maintain()
            self._schedule()
            self.util_samples.append(
                (self.now, sum(g for _, g in self.running.values()))
            )
            outstanding = (
                any(self.pending.values())
                or self.running
                or any(k == ARRIVAL for _, _, k, _ in self._events)
            )
            if outstanding and self.now < 24 * 3600:   # hard horizon
                self._push_round(self.now + self.cfg.round_interval)
            self._emit(ROUND)
        return True

    def finish(self) -> SimResult:
        """Close out a (possibly partial) run: anything still pending is
        recorded as an SLO violation, and the accumulated result is
        returned. Running again later continues from this state."""
        for q in self.pending.values():
            for j in q:
                self.records.append(
                    JobRecord(job=j, gpus=0, used_bank=False,
                              start=float("inf"), finish=float("inf"),
                              violated=True, wait=float("inf"),
                              init_overhead=0.0)
                )
                self.outstanding_jobs -= 1
            q.clear()
        return self.result()

    def result(self) -> SimResult:
        """The accumulated SimResult so far (no draining side effects).

        The shared-pool ledger row is derived here: whatever slice of
        the globally billed GPU-seconds is not attributed to a tenant's
        completed jobs — idle/warming warm capacity, a static cluster's
        slack, and (mid-run) still-running jobs whose busy time settles
        onto their tenant at completion."""
        gpu_bt = dict(self.gpu_seconds_by_tenant)
        cost_bt = dict(self.cost_by_tenant)
        shared_s = self.gpu_seconds - sum(gpu_bt.values())
        if shared_s > 1e-9:
            gpu_bt[SHARED_POOL] = shared_s
            cost_bt[SHARED_POOL] = shared_s * self.cfg.price_per_gpu_s
        return SimResult(
            records=self.records,
            cost=self.cost,
            gpu_seconds=self.gpu_seconds,
            makespan=self.now,
            util_samples=self.util_samples,
            cost_by_tenant=cost_bt,
            gpu_seconds_by_tenant=gpu_bt,
        )

    def run(self, jobs: Sequence[Job] = ()) -> SimResult:
        """Drive the event loop until no work is outstanding. May be
        called repeatedly (the service facade submits between calls);
        time and records accumulate monotonically."""
        self.begin(jobs)
        while self.step():
            pass
        return self.finish()


# Deprecated alias: the pre-registry base class. Subclass ClusterEngine
# (overriding _schedule) or, preferably, write a SchedulingPolicy.
ClusterSim = ClusterEngine
