"""Fault plane: deterministic failure injection + crash-aware recovery.

Real fleets lose shards — instances crash, spot capacity is preempted
with seconds of warning, stragglers run slow, and flaky hosts flap.
SLO-Guard's argument (PAPERS.md) is that an SLO system's numbers are
only believable when its accounting survives exactly these events. The
:class:`FaultPlane` injects them into a running
:class:`~repro.cluster.fabric.ClusterFabric` deterministically (seeded,
schedule- or hazard-rate-driven), and the recovery half of the stack
puts the work back:

* **shard crash** — every replica lost at once: running jobs are killed
  (checkpointed progress credited, see ``SimConfig.checkpoint_*``),
  queued jobs and undelivered arrivals are orphaned, the shard stops
  billing and attracting placement;
* **spot preemption** — a crash announced ``lead_s`` early via a
  :data:`SHARD_WARNED` event; a failure-aware
  :class:`~repro.cluster.elastic.ElasticController` drains the shard
  proactively during the warning window;
* **transient slowdown** — a per-shard step-time multiplier (straggler)
  applied to jobs started while it lasts;
* **flapping** — repeated crash/recover cycles; the controller
  quarantines shards whose recent failure count crosses its threshold.

Orphaned jobs are re-queued through fabric placement with exponential
backoff and a per-job retry budget (:class:`RecoveryPolicy`); a job
whose budget is exhausted — or that no capacity can ever serve again —
is *shed*: recorded as a violated terminal record so every submitted
job still resolves to exactly one outcome. All transitions flow as
typed events (:data:`SHARD_FAILED` / :data:`SHARD_RECOVERED` /
:data:`JOB_ORPHANED` / :data:`JOB_RETRIED` / :data:`JOB_SHED`) into the
fabric's existing ``on_event`` stream, so the telemetry plane renders
failure lifecycles with no extra wiring.

The plane keeps its own time-ordered action heap which the fabric's run
loop interleaves with engine events (an injection or retry fires at its
exact simulated time even when every engine is idle). With no plane
attached — the default — the fabric is bit-identical to the pre-fault
code path (pinned in ``tests/test_faults.py``).
"""
from __future__ import annotations

import heapq
import itertools
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.jobs import Job

# Fault-lifecycle event kinds, alongside the engine's ARRIVAL/ROUND/
# JOB_DONE and the elastic plane's stolen/resized/rejected.
SHARD_FAILED = "shard_failed"        # crash or preemption landed
SHARD_RECOVERED = "shard_recovered"  # capacity restored after downtime
SHARD_WARNED = "shard_warned"        # spot preemption announced (lead time)
SHARD_SLOWED = "shard_slowed"        # straggler multiplier applied/cleared
JOB_ORPHANED = "job_orphaned"        # a job lost its shard mid-flight
JOB_RETRIED = "job_retried"          # an orphan re-entered placement
JOB_SHED = "job_shed"                # terminal: retry budget/capacity gone


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault. ``kind`` selects which knobs apply:

    * ``"crash"`` — at ``time``, shard dies; back after ``down_s``
      (``None``: stays down);
    * ``"preempt"`` — warning at ``time``, kill at ``time + lead_s``,
      back after ``down_s``;
    * ``"slow"`` — step-time multiplied by ``factor`` for
      ``duration_s``;
    * ``"flap"`` — ``cycles`` crash/recover cycles spaced ``period_s``
      apart, each down for ``down_s`` (default: half the period).
    """

    kind: str
    time: float
    shard: int
    down_s: Optional[float] = None
    lead_s: float = 30.0
    factor: float = 2.0
    duration_s: float = 120.0
    cycles: int = 3
    period_s: float = 60.0


@dataclass(frozen=True)
class HazardConfig:
    """Random fault generation: expected events per shard per hour for
    each fault type, expanded into a concrete seeded schedule over
    ``horizon_s`` at :meth:`FaultPlane.attach` (exponential inter-
    arrivals and downtimes — memoryless spot behaviour)."""

    crash_rate: float = 0.0           # crashes / shard / hour
    preempt_rate: float = 0.0         # preemptions / shard / hour
    slow_rate: float = 0.0            # slowdown episodes / shard / hour
    flap_rate: float = 0.0            # flapping bursts / shard / hour
    mean_downtime_s: float = 120.0
    preempt_lead_s: float = 30.0
    slow_factor: float = 2.0
    mean_slow_duration_s: float = 180.0
    flap_cycles: int = 3
    flap_period_s: float = 60.0
    horizon_s: float = 1200.0


@dataclass(frozen=True)
class RecoveryPolicy:
    """Retry semantics for orphaned jobs: attempt ``k`` (1-based) is
    re-placed ``min(backoff_base_s * 2**(k-1), backoff_cap_s)`` after
    the orphaning; past ``max_retries`` the job is shed."""

    max_retries: int = 3
    backoff_base_s: float = 5.0
    backoff_cap_s: float = 120.0


# Named chaos profiles the benchmarks / CLI sweep over.
CHAOS_PROFILES: Dict[str, HazardConfig] = {
    "crashes": HazardConfig(crash_rate=5.0, mean_downtime_s=150.0),
    "preemptions": HazardConfig(preempt_rate=5.0, preempt_lead_s=45.0,
                                mean_downtime_s=240.0),
    "mixed": HazardConfig(crash_rate=2.5, preempt_rate=2.5, slow_rate=2.0,
                          flap_rate=1.0, mean_downtime_s=150.0,
                          preempt_lead_s=45.0),
}


class FaultPlane:
    """Injects faults into one fabric and owns the recovery bookkeeping.

    Construct with an explicit ``schedule`` (a sequence of
    :class:`FaultEvent`), a :class:`HazardConfig` (expanded with
    ``seed`` once the shard count is known), or both; pass the plane to
    ``ClusterFabric(..., faults=plane)``, which calls :meth:`attach`.
    The same seed + schedule + workload replays the identical failure
    history — chaos runs are exactly reproducible.
    """

    def __init__(self, schedule: Sequence[FaultEvent] = (), *,
                 hazard: Optional[HazardConfig] = None, seed: int = 0,
                 recovery: Optional[RecoveryPolicy] = None):
        self.schedule = list(schedule)
        self.hazard = hazard
        self.seed = seed
        self.recovery = recovery or RecoveryPolicy()
        self.fabric = None
        self.audit = None              # duck-typed AuditLog sink (obs)
        # lifecycle counters (introspection / benchmarks)
        self.crashes = 0
        self.preemptions = 0
        self.warnings = 0
        self.slowdowns = 0
        self.recoveries = 0
        self.retries = 0
        self.sheds = 0
        self.warned: Dict[int, float] = {}       # shard -> kill time
        self._down: Dict[int, int] = {}          # shard -> capacity lost
        self._failures: Dict[int, List[float]] = {}   # shard -> crash times
        self._attempts: Dict[int, int] = {}      # job_id -> retries used
        self._seq = itertools.count()
        self._actions: List[Tuple[float, int, str, int, object]] = []

    # -- wiring ---------------------------------------------------------------

    def attach(self, fabric) -> "FaultPlane":
        """Bind to ``fabric`` and expand the schedule (and any hazard
        config, now that the shard count is known) into the action
        heap. Called by the fabric constructor; attach exactly once."""
        if self.fabric is not None:
            raise ValueError("FaultPlane is already attached to a fabric; "
                             "use one plane per fabric")
        self.fabric = fabric
        for f in self.schedule:
            self._expand(f)
        if self.hazard is not None:
            for f in self._hazard_schedule(len(fabric.shards)):
                self._expand(f)
        return self

    def _hazard_schedule(self, shards: int) -> List[FaultEvent]:
        hz = self.hazard
        rng = random.Random(self.seed)
        out: List[FaultEvent] = []
        kinds = (("crash", hz.crash_rate), ("preempt", hz.preempt_rate),
                 ("slow", hz.slow_rate), ("flap", hz.flap_rate))
        for shard in range(shards):
            for kind, rate in kinds:
                if rate <= 0:
                    continue
                t = rng.expovariate(rate / 3600.0)
                while t < hz.horizon_s:
                    out.append(FaultEvent(
                        kind=kind, time=t, shard=shard,
                        down_s=rng.expovariate(1.0 / hz.mean_downtime_s),
                        lead_s=hz.preempt_lead_s,
                        factor=hz.slow_factor,
                        duration_s=rng.expovariate(
                            1.0 / hz.mean_slow_duration_s),
                        cycles=hz.flap_cycles,
                        period_s=hz.flap_period_s))
                    t += rng.expovariate(rate / 3600.0)
        out.sort(key=lambda f: (f.time, f.shard, f.kind))
        return out

    def _expand(self, f: FaultEvent) -> None:
        if f.kind == "crash":
            self._push(f.time, "crash", f.shard, f.down_s)
        elif f.kind == "preempt":
            self._push(f.time, "warn", f.shard, (f.lead_s, f.down_s))
        elif f.kind == "slow":
            self._push(f.time, "slow", f.shard, f.factor)
            self._push(f.time + f.duration_s, "unslow", f.shard, None)
        elif f.kind == "flap":
            down = f.down_s if f.down_s is not None else f.period_s / 2.0
            for c in range(f.cycles):
                self._push(f.time + c * f.period_s, "crash", f.shard, down)
        else:
            raise ValueError(f"unknown fault kind {f.kind!r}; expected "
                             "crash | preempt | slow | flap")

    def _push(self, t: float, kind: str, shard: int, payload) -> None:
        heapq.heappush(self._actions,
                       (t, next(self._seq), kind, shard, payload))

    # -- run-loop surface (consumed by ClusterFabric.run) ---------------------

    def next_time(self) -> Optional[float]:
        return self._actions[0][0] if self._actions else None

    def fire_next(self) -> None:
        """Apply the earliest queued action through fabric verbs."""
        t, _, kind, shard, payload = heapq.heappop(self._actions)
        if kind == "crash":
            self._kill(shard, t, payload, reason="crash")
        elif kind == "warn":
            lead, down = payload
            if shard in self._down:
                return                 # already dead: nothing to warn about
            self.warnings += 1
            self.warned[shard] = t + lead
            self._audit(t, SHARD_WARNED, shard,
                        detail=f"spot preemption in {lead:g}s")
            self.fabric.warn_shard(shard, t, kill_at=t + lead)
            self._push(t + lead, "preempt", shard, down)
        elif kind == "preempt":
            self.warned.pop(shard, None)
            self._kill(shard, t, payload, reason="spot preemption")
        elif kind == "recover":
            if shard in self._down:
                cap = self._down.pop(shard)
                self.recoveries += 1
                self._failures.setdefault(shard, [])
                self._audit(t, SHARD_RECOVERED, shard,
                            detail=f"+{cap} GPUs restored")
                self.fabric.recover_shard(shard, cap, t)
        elif kind == "slow":
            if shard not in self._down:
                self.slowdowns += 1
                # the factor rides in inputs so forensics can rebuild
                # per-shard slowdown windows from the audit log alone
                self._audit(t, SHARD_SLOWED, shard,
                            detail=f"x{payload:g} step time",
                            inputs={"factor": payload})
                self.fabric.slow_shard(shard, payload, t)
        elif kind == "unslow":
            if shard not in self._down:
                self._audit(t, SHARD_SLOWED, shard,
                            detail="x1 step time (cleared)",
                            inputs={"factor": 1.0})
                self.fabric.slow_shard(shard, 1.0, t)
        elif kind == "retry":
            self._fire_retry(payload, t)

    def _kill(self, shard: int, t: float, down_s, *, reason: str) -> None:
        if shard in self._down:
            return                     # double-kill: already dead
        if reason == "crash":
            self.crashes += 1
            # only unannounced crashes feed the flap signal: a warned
            # spot preemption is normal churn, and quarantining the
            # capacity when it returns would just waste it
            self._failures.setdefault(shard, []).append(t)
        else:
            self.preemptions += 1
        self.warned.pop(shard, None)
        # mark down *before* fail_shard: the orphan callbacks it runs
        # (retry scheduling, immediate sheds) must see the shard as dead
        self._down[shard] = 0
        # an announced kill (spot preemption) had a warning lead to flush
        # a final snapshot; an unannounced crash only keeps whole blocks
        orphans, lost = self.fabric.fail_shard(
            shard, t, reason=reason, final_snapshot=reason != "crash")
        self._down[shard] = lost
        self._audit(t, SHARD_FAILED, shard,
                    detail=f"{reason}: -{lost} GPUs, "
                           f"{len(orphans)} jobs orphaned")
        if down_s is not None:
            self._push(t + down_s, "recover", shard, None)

    # -- orphan retry / shed --------------------------------------------------

    def on_orphaned(self, job: Job, t: float) -> None:
        """Called by ``fabric.fail_shard`` per orphan: schedule a backed-
        off retry, or shed when the per-job budget is spent."""
        used = self._attempts.get(job.job_id, 0)
        if used >= self.recovery.max_retries:
            self.shed(job, t, f"retry budget exhausted "
                              f"({used}/{self.recovery.max_retries})")
            return
        self._attempts[job.job_id] = used + 1
        backoff = min(self.recovery.backoff_base_s * (2 ** used),
                      self.recovery.backoff_cap_s)
        self._push(t + backoff, "retry", -1, job)

    def _fire_retry(self, job: Job, t: float) -> None:
        attempt = self._attempts.get(job.job_id, 0)
        if self.fabric.requeue(job, t, attempt=attempt):
            self.retries += 1
            self._audit(t, JOB_RETRIED, self.fabric.placed.get(job.job_id, -1),
                        job_id=job.job_id, tenant=job.tenant,
                        detail=f"attempt {attempt}")
            return
        # No shard can hold a replica right now. If a recovery is still
        # queued, park the retry until right after it lands; otherwise
        # the capacity is gone for good and the job is shed.
        for ts, _, kind, _, _ in sorted(self._actions):
            if kind == "recover":
                self._push(max(ts, t), "retry", -1, job)
                return
        self.shed(job, t, "no shard capacity left to retry on")

    def shed(self, job: Job, t: float, reason: str) -> None:
        self.sheds += 1
        self._audit(t, JOB_SHED, -1, job_id=job.job_id, tenant=job.tenant,
                    detail=reason)
        self.fabric.shed_job(job, t, reason)

    # -- introspection (controller / tests / benchmarks) ----------------------

    def is_down(self, shard: int) -> bool:
        return shard in self._down

    def placeable(self, shard: int) -> bool:
        """Should new work land on ``shard``? Not while it is dead or
        inside a preemption-warning window."""
        return shard not in self._down and shard not in self.warned

    def capacity_lost(self) -> int:
        """GPUs currently failed out of the fleet (restored on
        recovery) — the conservation term the property tests pin."""
        return sum(self._down.values())

    def recent_failures(self, shard: int, now: float,
                        window: float) -> int:
        """Crash/preempt count on ``shard`` within ``window`` seconds —
        the flap signal the controller quarantines on."""
        return sum(1 for ts in self._failures.get(shard, ())
                   if now - ts <= window)

    def retries_used(self, job_id: int) -> int:
        return self._attempts.get(job_id, 0)

    def _audit(self, t: float, action: str, shard: int, *,
               job_id: Optional[int] = None, tenant: Optional[str] = None,
               detail: str = "", inputs: Optional[Dict] = None) -> None:
        if self.audit is not None:
            self.audit.decision(time=t, action=action, shard=shard,
                                job_id=job_id, tenant=tenant, detail=detail,
                                inputs=inputs)
