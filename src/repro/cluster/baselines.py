"""Baseline cluster-management systems (§3, §6.1).

**INFless** [85] — SLO-aware serverless DL *inference* system, reinforced
per the paper with (a) multi-GPU execution over a Memcached channel and
(b) the Prompt Bank, for a fair comparison. Characteristics modeled:
  * per-model instance autoscaling with a keep-alive window (billed while
    alive, busy or idle),
  * one GPU per instance; a multi-GPU job starts only when ALL of its
    instances are up — warm instances connect in ~2 s but each cold
    instance pays the full container/runtime/weights bring-up, so the job
    start time is the MAX over instance inits (the straggler effect of
    Fig 3b, 11-50 % of end-to-end latency),
  * no global schedule: per-model FIFO, no delayed execution.

**ElasticFlow** [41] — SLO-aware elastic DL *training* system:
  * a statically provisioned fixed-size cluster (all ``max_gpus`` billed
    for the whole experiment — Inefficiency 1),
  * deadline-ordered admission with minimum-satisfactory-share
    allocation (its core algorithm),
  * elastic (it can choose any GPU count), but every job start pays the
    cold bring-up: no runtime reuse across jobs.
"""
from __future__ import annotations

from typing import Dict, List

from repro.cluster.sim import ClusterSim, SimConfig
from repro.core.jobs import Job, exec_time


class INFlessSim(ClusterSim):
    name = "infless"

    # Serverless keep-alive is tuned for single-GPU inference traffic;
    # multi-instance LPT jobs release whole gangs at once, so the idle
    # tail INFless pays for is ~2x the per-model window PromptTuner's
    # demand-driven reclaim holds (its scheduler returns GPUs as soon as
    # the warm pool exceeds pending demand).
    KEEP_ALIVE_FACTOR = 2.0
    # container bring-up is heavy-tailed (Fig 3b: init is 11 % of e2e
    # latency on average, up to 50 %): each cold instance draws its init
    # time from cold_overhead x U(0.8, 2.2); a multi-instance gang waits
    # for the slowest (the straggler the warm allocator avoids).
    INIT_JITTER = (0.8, 2.2)

    def __init__(self, cfg: SimConfig):
        super().__init__(cfg)
        import numpy as np
        self._rng = np.random.default_rng(12345)

    def billed_gpus(self) -> int:
        return sum(p.total() for p in self.pools.values())

    def _maintain(self) -> None:
        for llm, p in self.pools.items():
            p.mature(self.now)
            # keep-alive: idle instances die after the window
            self.cold_free += p.reclaim(
                self.now, self.cfg.keep_alive * self.KEEP_ALIVE_FACTOR)

    def _schedule(self) -> None:
        for llm, queue in self.pending.items():
            if not queue:
                continue
            pool = self.pool(llm)
            prof = queue[0].profile()
            queue.sort(key=lambda j: j.submit_time)      # FIFO, no global sort
            leftover: List[Job] = []
            for job in queue:
                used_bank = self.use_bank_for(job)
                slo_rem = job.deadline - self.now
                avail = len(pool.idle) + self.cold_free
                max_rep = min(avail // prof.gpus_per_replica,
                              self.cfg.max_replicas_per_job)
                if max_rep < 1:
                    leftover.append(job)
                    continue
                # grow instances until the SLO fits. INFless is SLO-aware
                # about startup: it uses the cold bring-up estimate once
                # the allocation exceeds the warm instances. The remaining
                # inefficiency (the paper's #2) is the STRAGGLER: one cold
                # instance delays the whole multi-instance gang.
                a = 1
                while a < max_rep:
                    g = a * prof.gpus_per_replica
                    oh = (prof.warm_overhead if g <= len(pool.idle)
                          else prof.cold_overhead)
                    if exec_time(job, g, used_bank=used_bank,
                                 alloc_overhead=oh) <= slo_rem:
                        break
                    a += 1
                g = a * prof.gpus_per_replica
                n_warm = min(len(pool.idle), g)
                n_cold = g - n_warm
                pool.take_idle(n_warm)
                if n_cold:
                    self.cold_free -= n_cold
                    pool.busy += n_cold
                # straggler: the job waits for the SLOWEST instance init
                if n_cold:
                    jitter = self._rng.uniform(*self.INIT_JITTER,
                                               size=n_cold).max()
                    overhead = prof.cold_overhead * float(jitter)
                else:
                    overhead = prof.warm_overhead
                self.start_job(job, g, overhead, used_bank)
            self.pending[llm] = leftover


class ElasticFlowSim(ClusterSim):
    name = "elasticflow"

    def __init__(self, cfg: SimConfig):
        super().__init__(cfg)
        self.free = cfg.max_gpus

    def billed_gpus(self) -> int:
        return self.cfg.max_gpus          # static provisioning: always billed

    def _maintain(self) -> None:
        pass                              # no pools to mature/reclaim

    def _on_job_done(self, job: Job, gpus: int) -> None:
        self.free += gpus

    def _schedule(self) -> None:
        # global deadline order (ElasticFlow's admission control)
        all_pending: List[Job] = [j for q in self.pending.values() for j in q]
        all_pending.sort(key=lambda j: j.deadline)
        started = set()
        for job in all_pending:
            prof = job.profile()
            used_bank = self.use_bank_for(job)
            slo_rem = job.deadline - self.now
            max_rep = min(self.free // prof.gpus_per_replica,
                          self.cfg.max_replicas_per_job)
            if max_rep < 1:
                continue
            a = 1
            while (exec_time(job, a * prof.gpus_per_replica,
                             used_bank=used_bank,
                             alloc_overhead=prof.cold_overhead) > slo_rem
                   and a < max_rep):
                a += 1
            g = a * prof.gpus_per_replica
            feasible = exec_time(job, g, used_bank=used_bank,
                                 alloc_overhead=prof.cold_overhead) <= slo_rem
            hopeless = exec_time(
                job, max_rep * prof.gpus_per_replica, used_bank=used_bank,
                alloc_overhead=prof.cold_overhead) > slo_rem
            if feasible or (hopeless and self.cfg.best_effort):
                if hopeless:
                    g = prof.gpus_per_replica     # best effort: min share
                self.free -= g
                # every start is a cold bring-up: no runtime reuse
                self.start_job(job, g, prof.cold_overhead, used_bank)
                started.add(job.job_id)
        for llm in self.pending:
            self.pending[llm] = [j for j in self.pending[llm]
                                 if j.job_id not in started]


SYSTEMS = {
    "prompttuner": None,   # filled lazily to avoid a circular import
    "infless": INFlessSim,
    "elasticflow": ElasticFlowSim,
}


def make_system(name: str, cfg: SimConfig) -> ClusterSim:
    if name == "prompttuner":
        from repro.core.scheduler import PromptTunerSim
        return PromptTunerSim(cfg)
    return SYSTEMS[name](cfg)
