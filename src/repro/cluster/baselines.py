"""Deprecated shims for the baseline systems (§3, §6.1).

The INFless and ElasticFlow models now live in
:mod:`repro.cluster.policies` (``infless.py`` / ``elasticflow.py``);
this module keeps the old class names importable as one-line policy
wrappers. Prefer::

    from repro.cluster import policies
    engine = policies.build("infless", cfg)
"""
from __future__ import annotations

from repro.cluster.engine import ClusterEngine, SimConfig
from repro.cluster.policies import available, get


class INFlessSim(ClusterEngine):
    """Deprecated: use ``policies.build('infless', cfg)``."""

    def __init__(self, cfg: SimConfig):
        super().__init__(cfg, get("infless")(cfg))


class ElasticFlowSim(ClusterEngine):
    """Deprecated: use ``policies.build('elasticflow', cfg)``."""

    def __init__(self, cfg: SimConfig):
        super().__init__(cfg, get("elasticflow")(cfg))


# name -> policy class, for callers that used to introspect this dict
SYSTEMS = {name: get(name) for name in available()}


def make_system(name: str, cfg: SimConfig) -> ClusterEngine:
    """Deprecated alias of ``policies.build(name, cfg)``."""
    return ClusterEngine(cfg, get(name)(cfg))
