"""Production meshes and sharding helpers.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model); the batch
shards over (pod, data) jointly and parameters/caches over model.

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state (the dry-run driver sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; tests and benches see the single real CPU device).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over however many real devices exist (CPU testing)."""
    n = len(jax.devices())
    assert data * model <= n, f"need {data * model} devices, have {n}"
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes the global batch shards over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh: Mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    size = 1
    for n in names:
        size *= mesh.shape[n] if n in mesh.axis_names else 1
    return size


def batch_spec(mesh: Mesh, global_batch: int, ndim: int, *,
               seq_dim: Optional[int] = None, seq_len: int = 0) -> P:
    """Shard dim 0 (batch) over the data axes when divisible; otherwise
    fall back to sharding the sequence dim (long-context, batch==1)."""
    da = data_axes(mesh)
    ds = axis_size(mesh, da)
    spec = [None] * ndim
    if global_batch % ds == 0 and global_batch >= ds:
        spec[0] = da if len(da) > 1 else da[0]
    elif seq_dim is not None and seq_len % ds == 0 and seq_len >= ds:
        spec[seq_dim] = da if len(da) > 1 else da[0]
    return P(*spec)


def model_axis_size(mesh: Mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def cache_partition_specs(cache_abstract, mesh: Mesh) -> object:
    """Heuristic KV-cache/state sharding.

    Leaf layouts (leading ``count`` = layers-in-segment stack dim):
      kv:      (count, B, L, Hkv, hd)        mla: (count, B, L, r)
      rwkv s:  (count, B, H, hd, hd)         mamba h: (count, B, H, ds, hd)
    Policy: shard batch over data axes when divisible, else the length
    dim (dim 2); shard the first remaining head-ish dim that divides the
    model axis over ``model``.
    """
    da = data_axes(mesh)
    ds = axis_size(mesh, da)
    ms = model_axis_size(mesh)
    da_entry = da if len(da) > 1 else (da[0] if da else None)

    def leaf(a) -> P:
        shape = a.shape
        nd = len(shape)
        spec = [None] * nd
        used = set()
        if nd >= 2 and shape[1] % ds == 0 and shape[1] >= ds and ds > 1:
            spec[1] = da_entry
            used.add(1)
        elif nd >= 3 and shape[2] % ds == 0 and shape[2] >= ds and ds > 1:
            spec[2] = da_entry
            used.add(2)
        if ms > 1:
            # prefer head-ish dims (3+) over the length dim (2): sharding
            # cache length over `model` would force per-step resharding
            for i in list(range(3, nd)) + [2]:
                if i in used or i >= nd:
                    continue
                if shape[i] % ms == 0 and shape[i] >= ms:
                    spec[i] = "model"
                    break
        return P(*spec)

    return jax.tree.map(leaf, cache_abstract)
