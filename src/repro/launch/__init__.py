# NOTE: repro.launch.dryrun must be imported FIRST (it sets XLA_FLAGS for
# the 512-device host platform) when doing dry-runs; import it directly as
# `python -m repro.launch.dryrun`. This package init deliberately imports
# nothing that touches jax device state.
from repro.launch.mesh import (
    cache_partition_specs,
    data_axes,
    make_debug_mesh,
    make_production_mesh,
    model_axis_size,
)

__all__ = [
    "cache_partition_specs",
    "data_axes",
    "make_debug_mesh",
    "make_production_mesh",
    "model_axis_size",
]
