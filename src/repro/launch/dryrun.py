import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_DRYRUN_XLA", "--xla_force_host_platform_device_count=512")
)
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape) pair this lowers + compiles the
matching step function on the production mesh — 16x16 (single pod) and
2x16x16 (two pods) — and extracts:

  * ``compiled.memory_analysis()``  (bytes/device: proves it fits),
  * ``compiled.cost_analysis()``    (HLO FLOPs / bytes for the roofline),
  * collective bytes parsed from the HLO text (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute operand sizes).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi --out artifacts/dryrun
Results are appended as JSON lines to ``--out`` (default
``artifacts/dryrun/<mesh>.jsonl``).
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.config import INPUT_SHAPES, TuneConfig
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.roofline.extract import (
    cost_summary,
    memory_summary,
    model_flops,
    roofline_terms,
)
from repro.roofline.hlo_analysis import analyze_hlo


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               microbatches: int = 0, ce_chunk: int = 512,
               seq_shard: bool = False, keep_hlo: bool = False,
               verbose: bool = True) -> Dict[str, Any]:
    """Lower + compile one (arch, shape) on the production mesh."""
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    if seq_shard:
        cfg = cfg.with_overrides(seq_shard=True)
    if microbatches == 0:   # auto: grad accumulation keeps train in HBM,
        # but each microbatch must still give >= 1 row per data shard
        shape = INPUT_SHAPES[shape_name]
        if shape.kind == "train":
            data_ways = 32 if multi_pod else 16
            microbatches = max(1, min(16, shape.global_batch // data_ways))
        else:
            microbatches = 1
    fn, specs, shardings, model = build_step(
        cfg, shape_name, mesh, microbatches=microbatches, ce_chunk=ce_chunk
    )
    with mesh:
        jitted = jax.jit(
            fn,
            in_shardings=tuple(shardings[k] for k in specs),
        )
        lowered = jitted.lower(*(specs[k] for k in specs))
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    hlo = compiled.as_text()
    hc = analyze_hlo(hlo)           # trip-count-aware per-device costs
    coll = hc["collectives"]
    shape = INPUT_SHAPES[shape_name]
    terms = roofline_terms(hc["flops"], hc["bytes"], coll["total_bytes"])
    mf = model_flops(cfg, shape, backward=(shape.kind == "train"))
    mf_dev = mf / mesh.devices.size
    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "seq_shard": seq_shard,
        "n_devices": int(mesh.devices.size),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": memory_summary(compiled),
        "cost_raw": cost_summary(compiled),   # XLA view (scan bodies x1)
        "hlo_cost": {k: v for k, v in hc.items() if k != "collectives"},
        "collectives": coll,
        "roofline": terms,
        "model_flops_per_dev": mf_dev,
        "useful_flops_ratio": mf_dev / hc["flops"] if hc["flops"] else 0.0,
    }
    if keep_hlo:
        rec["hlo_text"] = hlo
    if verbose:
        m = rec["memory"]
        print(f"[dryrun] {arch} x {shape_name} ({rec['mesh']}): "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s | "
              f"args/dev {m.get('argument_size_in_bytes', 0)/1e9:.2f}GB "
              f"temp/dev {m.get('temp_size_in_bytes', 0)/1e9:.2f}GB | "
              f"comp {terms['compute_s']:.3f}s mem {terms['memory_s']:.3f}s "
              f"coll {terms['collective_s']:.3f}s -> {terms['dominant']} | "
              f"useful {rec['useful_flops_ratio']:.2f}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--ce-chunk", type=int, default=512)
    ap.add_argument("--seq-shard", action="store_true",
                    help="beyond-paper: context-parallel activations")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    multi = args.mesh == "multi"
    pairs = []
    if args.all:
        for a in ASSIGNED_ARCHS:
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    suffix = "_seqshard" if args.seq_shard else ""
    out_path = args.out or os.path.join(
        "artifacts", "dryrun", f"{args.mesh}{suffix}.jsonl"
    )
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    done = set()
    if os.path.exists(out_path):
        with open(out_path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if "error" not in r:
                        done.add((r["arch"], r["shape"]))
                except json.JSONDecodeError:
                    pass

    failures = 0
    with open(out_path, "a") as f:
        for arch, shape in pairs:
            if (arch, shape) in done:
                print(f"[dryrun] skip {arch} x {shape} (already recorded)")
                continue
            try:
                rec = dryrun_one(arch, shape, multi_pod=multi,
                                 microbatches=args.microbatches,
                                 ce_chunk=args.ce_chunk,
                                 seq_shard=args.seq_shard)
            except Exception as e:      # noqa: BLE001 — record and continue
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if multi else "16x16",
                       "error": repr(e)[:500]}
                failures += 1
            f.write(json.dumps(rec) + "\n")
            f.flush()
    print(f"[dryrun] complete; {failures} failures -> {out_path}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
