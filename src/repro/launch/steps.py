"""Step functions lowered by the launcher / dry-run driver.

Three entry points, matching the assigned input-shape kinds:

  * ``train``   — one LPT optimizer step (soft-prompt grads ONLY; model
                  weights frozen). Microbatched gradient accumulation via
                  ``jax.lax.scan`` when the global batch doesn't fit.
  * ``prefill`` — batched Eqn-1 scoring: backbone forward + chunked CE,
                  per-example losses. This is the Prompt Bank's hot path
                  and the LPT analog of inference prefill.
  * ``decode``  — one-token serve step against a KV cache of the given
                  length (``serve_step``).

``input_specs`` produces ShapeDtypeStruct stand-ins for every input
(weak-type-correct, shardable, no device allocation); ``step_shardings``
produces the matching ``in_shardings`` trees.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.config import InputShape, ModelConfig, TuneConfig, INPUT_SHAPES
from repro.launch import mesh as mesh_lib
from repro.models import Model, build_model
from repro.train.objectives import lpt_loss_chunked
from repro.train.optimizer import adam, apply_updates

# Sub-quadratic long-context policy (DESIGN.md §5): dense full-attention
# archs run long_500k with a sliding-window cache variant.
LONG_CONTEXT_WINDOW = 8192
SUBQUADRATIC_NATIVE = {"ssm", "hybrid"}      # recurrent state: native O(1)
MLA_COMPRESSED = "mla"                       # deepseek: O(L) latent cache


def model_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Apply per-shape config adaptations (sliding window for long decode
    on full-attention archs)."""
    if (
        shape.name == "long_500k"
        and cfg.arch_type not in SUBQUADRATIC_NATIVE
        and cfg.attention == "gqa"
        and cfg.sliding_window == 0
    ):
        return cfg.with_overrides(sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def supports_shape(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """All assigned archs support all four shapes (DESIGN.md §5): SSM /
    hybrid / MLA are natively sub-quadratic at 500k; dense GQA archs use
    the sliding-window variant."""
    return True, ""


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def make_train_step(model: Model, tune_cfg: TuneConfig, *,
                    microbatches: int = 1, ce_chunk: int = 512,
                    batch_axes: Tuple[str, ...] = ()):
    """(params, prompt_params, opt_state, batch) ->
    (prompt_params, opt_state, loss). Grads w.r.t. the prompt only.

    ``batch_axes``: mesh axes the per-microbatch batch dim must stay
    sharded over (the reshape to (m, B/m, ...) would otherwise let GSPMD
    move the sharding onto the scan axis, silently un-sharding each
    microbatch)."""
    opt = adam(tune_cfg.lr, weight_decay=tune_cfg.weight_decay)

    def loss_fn(prompt_params, params, batch):
        tot, (loss, _) = lpt_loss_chunked(
            model, params, prompt_params["soft_prompt"], batch, chunk=ce_chunk
        )
        return tot, loss

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, prompt_params, opt_state, batch):
        if microbatches == 1:
            (tot, loss), grads = grad_fn(prompt_params, params, batch)
        else:
            m = microbatches

            ba = (tuple(batch_axes) if len(batch_axes) != 1
                  else batch_axes[0]) or None

            def split(x):
                b = x.shape[0]
                y = x.reshape(m, b // m, *x.shape[1:])
                if ba is not None:
                    y = jax.lax.with_sharding_constraint(
                        y, P(None, ba, *([None] * (y.ndim - 2)))
                    )
                return y

            mb = {k: split(v) for k, v in batch.items()}

            def body(carry, xs):
                g_acc, l_acc = carry
                (tot, loss), g = grad_fn(prompt_params, params, xs)
                g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g)
                return (g_acc, l_acc + loss), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), prompt_params
            )
            (grads, loss), _ = jax.lax.scan(
                body, (zero, jnp.zeros((), jnp.float32)), mb
            )
            grads = jax.tree.map(lambda g: g / m, grads)
            loss = loss / m
        updates, new_opt = opt.update(grads, opt_state, prompt_params)
        new_prompt = apply_updates(prompt_params, updates)
        return new_prompt, new_opt, loss

    return train_step, opt


def make_prefill_step(model: Model, *, ce_chunk: int = 512):
    """Batched Eqn-1 scoring: (params, prompt_params, batch) -> (B,) loss."""

    def prefill_step(params, prompt_params, batch):
        tot, (loss, per_ex) = lpt_loss_chunked(
            model, params, prompt_params["soft_prompt"], batch, chunk=ce_chunk
        )
        return per_ex

    return prefill_step


def make_serve_step(model: Model):
    """One-token decode: (params, cache, tokens, cache_len) ->
    (next_token (B,1) i32, new_cache)."""

    def serve_step(params, cache, tokens, cache_len):
        logits, new_cache = model.decode_step(params, cache, tokens, cache_len)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, new_cache

    return serve_step


# ---------------------------------------------------------------------------
# Abstract inputs + shardings
# ---------------------------------------------------------------------------


def _frontend_spec(cfg: ModelConfig, batch: int):
    if cfg.frontend.kind == "none":
        return None
    return jax.ShapeDtypeStruct(
        (batch, cfg.frontend.num_embeddings, cfg.frontend.embed_dim),
        jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32,
    )


def batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    d = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
    }
    fe = _frontend_spec(cfg, B)
    if fe is not None:
        d["frontend"] = fe
    return d


def prompt_specs(cfg: ModelConfig, tune_cfg: TuneConfig) -> Dict[str, Any]:
    return {
        "soft_prompt": jax.ShapeDtypeStruct(
            (tune_cfg.prompt_len, cfg.d_model), jnp.float32
        )
    }


def input_specs(model: Model, shape: InputShape,
                tune_cfg: Optional[TuneConfig] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every step input, keyed by arg name."""
    cfg = model.cfg
    tune_cfg = tune_cfg or TuneConfig()
    if shape.kind == "train":
        pp = prompt_specs(cfg, tune_cfg)
        opt_state = jax.eval_shape(
            lambda: adam(tune_cfg.lr).init(
                jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), pp)
            )
        )
        return {
            "params": model.abstract_params(),
            "prompt_params": pp,
            "opt_state": opt_state,
            "batch": batch_specs(cfg, shape),
        }
    if shape.kind == "prefill":
        return {
            "params": model.abstract_params(),
            "prompt_params": prompt_specs(cfg, tune_cfg),
            "batch": batch_specs(cfg, shape),
        }
    if shape.kind == "decode":
        B = shape.global_batch
        return {
            "params": model.abstract_params(),
            "cache": model.abstract_cache(B, shape.seq_len),
            "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "cache_len": jax.ShapeDtypeStruct((), jnp.int32),
        }
    raise ValueError(shape.kind)


def step_shardings(model: Model, shape: InputShape, mesh: Mesh,
                   specs: Dict[str, Any]) -> Dict[str, Any]:
    """in_shardings tree matching :func:`input_specs`'s structure."""
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len
    param_sh = mesh_lib.tree_named(mesh, model.partition_specs())

    def dsh(ndim, seq_dim=None):
        return mesh_lib.named(
            mesh,
            mesh_lib.batch_spec(mesh, B, ndim, seq_dim=seq_dim, seq_len=S),
        )

    repl = mesh_lib.named(mesh, P())
    out: Dict[str, Any] = {}
    for key, val in specs.items():
        if key == "params":
            out[key] = param_sh
        elif key in ("prompt_params", "opt_state"):
            out[key] = jax.tree.map(lambda _: repl, val)
        elif key == "batch":
            out[key] = {
                k: dsh(v.ndim) for k, v in val.items()
            }
        elif key == "cache":
            cspecs = mesh_lib.cache_partition_specs(val, mesh)
            out[key] = mesh_lib.tree_named(mesh, cspecs)
        elif key == "tokens":
            out[key] = dsh(2)
        elif key == "cache_len":
            out[key] = repl
        else:
            raise KeyError(key)
    return out


def build_step(arch_cfg: ModelConfig, shape_name: str, mesh: Mesh, *,
               tune_cfg: Optional[TuneConfig] = None,
               microbatches: int = 1, ce_chunk: int = 512):
    """Assemble (step_fn, specs, shardings, model) for one (arch, shape)."""
    shape = INPUT_SHAPES[shape_name]
    tune_cfg = tune_cfg or TuneConfig()
    cfg = model_for_shape(arch_cfg, shape)
    data_size = mesh.shape["data"] if "data" in mesh.axis_names else 0
    model = build_model(cfg, model_axis=mesh_lib.model_axis_size(mesh),
                        data_axis=data_size, mesh=mesh)
    specs = input_specs(model, shape, tune_cfg)
    shardings = step_shardings(model, shape, mesh, specs)
    if shape.kind == "train":
        fn, _ = make_train_step(model, tune_cfg, microbatches=microbatches,
                                ce_chunk=ce_chunk,
                                batch_axes=mesh_lib.data_axes(mesh))
    elif shape.kind == "prefill":
        fn = make_prefill_step(model, ce_chunk=ce_chunk)
    else:
        fn = make_serve_step(model)
    return fn, specs, shardings, model
