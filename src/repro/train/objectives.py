"""Loss functions for LPT: masked next-token cross-entropy (Eqn 1's L)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def token_cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array):
    """logits: (B,S,V) f32; labels: (B,S) int32; mask: (B,S) {0,1}.

    Returns (mean_loss, per_example_loss (B,)). Mean is over masked tokens.
    """
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    per_ex = nll.sum(axis=-1) / jnp.maximum(mask.sum(axis=-1), 1.0)
    return nll.sum() / denom, per_ex


def chunked_token_cross_entropy(
    model, params, hidden, labels, mask, *, chunk: int = 512
):
    """Sequence-chunked CE: never materializes the full (B,S,V) logits.

    The unembedding projection + logsumexp + gold gather run one sequence
    chunk at a time under ``jax.lax.scan``; with a vocab-sharded embedding
    the per-device live set is (B/dp, chunk, V/mp) — the production-scale
    loss path (the Pallas ``score_ce`` kernel is its fused TPU twin; this
    is also the kernel's reference semantics).

    hidden: (B,S,d); labels/mask: (B,S). Returns (mean_loss, per_example).
    """
    from repro.models.common import unembed  # local import: avoid cycle

    B, S, d = hidden.shape
    chunk = min(chunk, S)
    if S % chunk != 0:                        # pad to a chunk multiple
        pad = chunk - S % chunk
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        S = S + pad
    nc = S // chunk
    hc = hidden.reshape(B, nc, chunk, d).swapaxes(0, 1)      # (nc,B,c,d)
    lc = labels.reshape(B, nc, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, nc, chunk).swapaxes(0, 1)

    def body(carry, xs):
        nll_sum, tok_sum = carry
        h, lab, msk = xs
        logits = unembed(model.cfg, params, h)               # (B,c,V) f32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * msk
        return (nll_sum + nll.sum(axis=-1), tok_sum + msk.sum(axis=-1)), None

    init = (jnp.zeros((B,), jnp.float32), jnp.zeros((B,), jnp.float32))
    (nll_sum, tok_sum), _ = jax.lax.scan(body, init, (hc, lc, mc))
    per_ex = nll_sum / jnp.maximum(tok_sum, 1.0)
    mean = nll_sum.sum() / jnp.maximum(tok_sum.sum(), 1.0)
    return mean, per_ex


def lpt_loss_chunked(model, params, prompt, batch, *, chunk: int = 512):
    """Production LPT loss: backbone forward + chunked CE over the token
    region. Same semantics as :func:`lpt_loss` up to summation order."""
    frontend = batch.get("frontend")
    hidden, aux = model.backbone(
        params, batch["tokens"], prompt=prompt, frontend=frontend
    )
    S = batch["tokens"].shape[1]
    h = hidden[:, -S:, :]
    loss, per_ex = chunked_token_cross_entropy(
        model, params, h, batch["labels"], batch["mask"], chunk=chunk
    )
    return loss + aux.get("aux_loss", 0.0), (loss, per_ex)


def lpt_loss(model, params, prompt, batch, prompt_len: int):
    """Loss of the model with a soft prompt prepended (the LPT objective).

    batch: {"tokens": (B,S), "labels": (B,S), "mask": (B,S)}. The prompt
    occupies positions [F, F+P); logits for the token region are shifted
    back out before the CE.
    """
    frontend = batch.get("frontend")
    logits, aux = model.forward(
        params, batch["tokens"], prompt=prompt, frontend=frontend
    )
    S = batch["tokens"].shape[1]
    tok_logits = logits[:, -S:, :]
    loss, per_ex = token_cross_entropy(tok_logits, batch["labels"], batch["mask"])
    return loss + aux.get("aux_loss", 0.0), (loss, per_ex)
