from repro.train.optimizer import (
    Optimizer,
    OptState,
    adam,
    apply_updates,
    cosine_schedule,
    make_optimizer,
    sgd,
)
from repro.train.objectives import lpt_loss, token_cross_entropy

__all__ = [
    "Optimizer",
    "OptState",
    "adam",
    "apply_updates",
    "cosine_schedule",
    "lpt_loss",
    "make_optimizer",
    "sgd",
    "token_cross_entropy",
]
