"""Pytree checkpointing to .npz (no external deps).

Trees are flattened to path-keyed arrays; restore rebuilds the nested dict.
Used for the pretrained base models, optimized prompt banks, and training
state. A tiny manifest records step and metadata.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}" if prefix else k))
    else:
        out[prefix] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]):
    root: Dict[str, Any] = {}
    for path, arr in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return root


def save_checkpoint(path: str, tree, step: int = 0, meta: Optional[Dict] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    np.savez_compressed(path, **flat)
    manifest = {"step": step, "meta": meta or {}, "keys": sorted(flat)}
    with open(path + ".json", "w") as f:
        json.dump(manifest, f, indent=1)


def load_checkpoint(path: str, as_jax: bool = True) -> Tuple[Any, Dict]:
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    tree = _unflatten({k: data[k] for k in data.files})
    if as_jax:
        import jax.numpy as jnp

        tree = jax.tree.map(jnp.asarray, tree)
    manifest = {}
    mpath = (path if path.endswith(".npz") else path + ".npz") + ".json"
    if os.path.exists(mpath):
        with open(mpath) as f:
            manifest = json.load(f)
    return tree, manifest


def checkpoint_exists(path: str) -> bool:
    return os.path.exists(path if path.endswith(".npz") else path + ".npz")
