"""Multi-task pretraining of the tiny testbed LLMs.

The paper uses pretrained GPT2/Vicuna checkpoints; offline we create the
analogous artifact by jointly training (model weights + a per-task prompt
table) on a mixture of synthetic task families. After this phase, a
*prompt prefix determines the task* — which is precisely the property
prompt tuning exploits — and the optimized per-task prompts seed the
Prompt Bank with genuinely high-quality candidates.

Artifacts are cached under ``artifacts/`` so tests and benchmarks re-use
them instead of re-training.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, TuneConfig
from repro.data import LoaderConfig, TaskLoader, TaskSpec, batch_to_jnp, make_tasks
from repro.models import Model, build_model
from repro.train.checkpoint import checkpoint_exists, load_checkpoint, save_checkpoint
from repro.train.objectives import lpt_loss
from repro.train.optimizer import adam, apply_updates

ARTIFACT_DIR = os.environ.get("REPRO_ARTIFACTS", "artifacts")


def testbed_config(name: str = "gpt2-base") -> ModelConfig:
    """Tiny CPU-trainable stand-ins for the paper's three LLMs; sizes are
    ordered like GPT2-Base < GPT2-Large < Vicuna-7B so relative results
    (e.g. Fig 9's per-LLM ITA speedups) are structurally comparable."""
    base = dict(
        arch_type="dense", num_kv_heads=2, head_dim=32, vocab_size=48,
        max_seq_len=128, norm="rmsnorm", activation="swiglu",
        dtype="float32", param_dtype="float32", remat=False,
    )
    sizes = {
        "gpt2-base": dict(num_layers=2, d_model=128, num_heads=4, d_ff=256),
        "gpt2-large": dict(num_layers=3, d_model=160, num_heads=4, d_ff=320),
        "vicuna-7b": dict(num_layers=4, d_model=192, num_heads=4, d_ff=384),
    }
    return ModelConfig(name=f"testbed-{name}", **base, **sizes[name])


@dataclass
class PretrainResult:
    model: Model
    params: Dict
    task_prompts: Dict[str, np.ndarray]   # task_id -> (P, d) optimized prompt
    tasks: List[TaskSpec]


# deeper testbed models need longer to cross the prompt-conditioning
# phase transition (measured: vicuna-7b converges ~16-24k steps)
DEFAULT_STEPS = {"gpt2-base": 8000, "gpt2-large": 8000, "vicuna-7b": 24000}


def pretrain(
    llm: str = "gpt2-base",
    *,
    steps: int = 0,
    prompt_len: int = 8,
    batch_size: int = 32,
    partitions: int = 4,
    seed: int = 0,
    cache: bool = True,
    verbose: bool = False,
) -> PretrainResult:
    steps = steps or DEFAULT_STEPS.get(llm, 8000)
    cfg = testbed_config(llm)
    model = build_model(cfg)
    tasks = make_tasks(vocab=32, partitions=partitions)
    path = os.path.join(ARTIFACT_DIR, f"pretrain_{llm}_s{steps}_p{partitions}.npz")

    if cache and checkpoint_exists(path):
        tree, manifest = load_checkpoint(path)
        params = tree["params"]
        table = np.asarray(tree["prompt_table"])
        prompts = {t.task_id: table[i] for i, t in enumerate(tasks)}
        return PretrainResult(model, params, prompts, tasks)

    key = jax.random.key(seed)
    n_tasks = len(tasks)
    d = cfg.d_model
    # warm-start from the largest smaller-step artifact of this run
    prev_path, prev_steps = None, 0
    if cache and os.path.isdir(ARTIFACT_DIR):
        import glob
        import re
        for f in glob.glob(os.path.join(
                ARTIFACT_DIR, f"pretrain_{llm}_s*_p{partitions}.npz")):
            m = re.search(r"_s(\d+)_p", f)
            if m and prev_steps < int(m.group(1)) < steps:
                prev_steps, prev_path = int(m.group(1)), f
    if prev_path is not None:
        tree, _ = load_checkpoint(prev_path)
        params = tree["params"]
        prompt_table = jnp.asarray(tree["prompt_table"])
        if verbose:
            print(f"[pretrain {llm}] warm start from s{prev_steps}")
    else:
        params = model.init(key)
        prompt_table = (
            jax.random.normal(jax.random.fold_in(key, 1),
                              (n_tasks, prompt_len, d))
            * (0.5 / np.sqrt(d))
        ).astype(jnp.float32)
    steps_to_run = steps - prev_steps

    from repro.train.optimizer import cosine_schedule
    opt = adam(cosine_schedule(2e-3, min(200, steps_to_run), steps_to_run))
    state = opt.init({"params": params, "prompts": prompt_table})

    def loss_fn(trainable, task_idx, batch):
        prompt = trainable["prompts"][task_idx]
        tot, (loss, _) = lpt_loss(model, trainable["params"], prompt, batch, prompt_len)
        return tot

    @jax.jit
    def step(trainable, opt_state, task_idx, batch):
        loss, grads = jax.value_and_grad(loss_fn)(trainable, task_idx, batch)
        updates, opt_state = opt.update(grads, opt_state, trainable)
        return apply_updates(trainable, updates), opt_state, loss

    rng = np.random.default_rng(seed)
    loaders = [
        TaskLoader(t, LoaderConfig(batch_size=batch_size, seed=seed)) for t in tasks
    ]
    trainable = {"params": params, "prompts": prompt_table}
    t0 = time.time()
    for it in range(steps_to_run):
        ti = int(rng.integers(n_tasks))
        batch = batch_to_jnp(next(loaders[ti]))
        trainable, state, loss = step(trainable, state, jnp.int32(ti), batch)
        if verbose and (it + 1) % 500 == 0:
            print(f"[pretrain {llm}] step {it+1}/{steps_to_run} "
                  f"loss {float(loss):.3f} ({time.time()-t0:.0f}s)")

    params = trainable["params"]
    table = np.asarray(trainable["prompts"])
    if cache:
        save_checkpoint(
            path,
            {"params": params, "prompt_table": table},
            step=steps,
            meta={"llm": llm, "tasks": [t.task_id for t in tasks]},
        )
    prompts = {t.task_id: table[i] for i, t in enumerate(tasks)}
    return PretrainResult(model, params, prompts, tasks)
