"""Pure-JAX optimizers (Adam/AdamW/SGD) and LR schedules.

Minimal optax-like interface: ``init(params) -> state``,
``update(grads, state, params) -> (updates, state)``; updates are ADDED to
params. Works on arbitrary pytrees (used for prompt-only parameter trees
in LPT, and whole-model trees in the training substrate tests).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], Tuple[Any, OptState]]


def _zeros_like_f32(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def adam(
    lr: float | Callable[[jax.Array], jax.Array],
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))

    def init(params) -> OptState:
        return OptState(jnp.zeros((), jnp.int32), _zeros_like_f32(params),
                        _zeros_like_f32(params))

    def update(grads, state: OptState, params):
        step = state.step + 1
        lr_t = lr_fn(step)
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads,
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            u = -lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u.astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, OptState(step, mu, nu)

    return Optimizer(init, update)


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params) -> OptState:
        return OptState(jnp.zeros((), jnp.int32), _zeros_like_f32(params), None)

    def update(grads, state: OptState, params):
        step = state.step + 1
        if momentum:
            mu = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32), state.mu, grads
            )
        else:
            mu = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        updates = jax.tree.map(lambda m, p: (-lr * m).astype(p.dtype), mu, params)
        return updates, OptState(step, mu if momentum else state.mu, None)

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return fn


def make_optimizer(name: str, lr, weight_decay: float = 0.0) -> Optimizer:
    if name == "adam":
        return adam(lr, weight_decay=weight_decay)
    if name == "adamw":
        return adam(lr, weight_decay=weight_decay or 0.01)
    if name == "sgd":
        return sgd(lr if not callable(lr) else 0.1)
    raise ValueError(name)
