from repro.data.synthetic import (
    BOS,
    FAMILIES,
    PAD,
    SEP,
    TaskSpec,
    batch_to_jnp,
    make_tasks,
    sample_batch,
    task_similarity,
)
from repro.data.pipeline import LoaderConfig, TaskLoader

__all__ = [
    "BOS",
    "FAMILIES",
    "LoaderConfig",
    "PAD",
    "SEP",
    "TaskLoader",
    "TaskSpec",
    "batch_to_jnp",
    "make_tasks",
    "sample_batch",
    "task_similarity",
]
