"""Host-side data pipeline: deterministic shard-aware batching.

For multi-host SPMD the loader yields per-host shards of the global batch
(host h takes rows [h*B/H, (h+1)*B/H)); on this single-process testbed the
host count is 1 and the loader degrades to simple batching. Prefetch is a
simple double-buffer (thread-free: CPU-bound synthetic generation)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.data.synthetic import TaskSpec, sample_batch


@dataclass
class LoaderConfig:
    batch_size: int = 8
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1


class TaskLoader:
    """Infinite iterator of batches for one LPT task."""

    def __init__(self, spec: TaskSpec, cfg: LoaderConfig):
        assert cfg.batch_size % cfg.num_hosts == 0
        self.spec = spec
        self.cfg = cfg
        self._rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, hash(spec.task_id) & 0x7FFFFFFF])
        )

    def __iter__(self) -> Iterator[Dict]:
        return self

    def __next__(self) -> Dict:
        global_b = self.cfg.batch_size
        batch = sample_batch(self.spec, self._rng, global_b)
        per = global_b // self.cfg.num_hosts
        lo = self.cfg.host_id * per
        return {k: v[lo : lo + per] for k, v in batch.items()}

    def eval_batch(self, n: int, seed: int = 1234) -> Dict:
        """Fixed evaluation set (the Eqn-1 D_eval, e.g. 16 samples)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, hash(self.spec.task_id) & 0x7FFFFFFF])
        )
        return sample_batch(self.spec, rng, n)
