"""Synthetic task families — the stand-in for the paper's 12 datasets
(Table 6: dialog, QA, text generation, summarization, story generation).

Each family is a parameterized seq2seq transformation over a small token
alphabet; family parameters play the role of dataset *partitions* (the
paper splits each dataset into 10 exclusive partitions -> 120 tasks).
Tasks within a family are *similar* — exactly the structure the Prompt
Bank exploits (prompts optimized for one partition transfer to others).

Sequence layout handed to the model:   [ input .. SEP target .. ]
labels[t] = token the model should predict at position t (pre-shifted);
mask = 1 on the target region only.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PAD, SEP, BOS = 0, 1, 2
N_SPECIAL = 3


@dataclass(frozen=True)
class TaskSpec:
    family: str
    param: int            # partition parameter (e.g. shift amount)
    vocab: int            # data alphabet size (excl. specials)
    input_len: int = 8
    target_len: int = 8

    @property
    def task_id(self) -> str:
        return f"{self.family}:{self.param}"


def _alphabet(spec: TaskSpec):
    return N_SPECIAL, spec.vocab


def _apply_family(family: str, param: int, x: np.ndarray, vocab: int) -> np.ndarray:
    """x: (B, L) ints in [0, vocab). Returns the target sequence.

    All 12 families are *prompt-conditioned (near-)local transforms*:
    y_i depends on x at a fixed relative offset plus a per-task vocabulary
    map. A 2-layer testbed LLM learns these within a few thousand
    multitask steps (one fixed-offset attention pattern + a prompt-gated
    token map) — which is what lets the ITA / prompt-sensitivity
    experiments run end-to-end on CPU. Within a family, nearby ``param``
    values yield similar tasks: the transfer structure the Prompt Bank
    exploits (§4.1 insight 1).
    """
    L = x.shape[1]
    pos = np.arange(L)[None, :]
    if family == "copy":                        # identity, tiny rotation
        return (x + (param % 3)) % vocab
    if family == "shift":                       # add param+3 mod vocab
        return (x + param + 3) % vocab
    if family == "negate":                      # mirror alphabet with offset
        return (vocab - 1 - x + param) % vocab
    if family == "mul":                         # odd multiplier => bijection
        return (x * (2 * param + 3)) % vocab
    if family == "affine":                      # 3x + odd offset
        return (3 * x + 2 * param + 1) % vocab
    if family == "xor":                         # bitwise xor (vocab power of 2)
        assert vocab & (vocab - 1) == 0, "xor family needs power-of-2 vocab"
        return x ^ ((param + 1) % vocab)
    if family == "bitrev":                      # reverse bits, then + param
        nbits = int(np.log2(vocab))
        y = np.zeros_like(x)
        for b in range(nbits):
            y |= ((x >> b) & 1) << (nbits - 1 - b)
        return (y + param) % vocab
    if family == "parity_swap":                 # +-(param+1) by token parity
        return np.where(x % 2 == 0, x + param + 1, x - param - 1) % vocab
    if family == "add_pos":                     # + position + param
        return (x + pos + param) % vocab
    if family == "alt_shift":                   # +p at even positions, -p at odd
        return (x + np.where(pos % 2 == 0, param + 1, -(param + 1))) % vocab
    if family == "prev":                        # y_i = x_{i-1} + p (y_0 = x_0 + p)
        y = np.concatenate([x[:, :1], x[:, :-1]], axis=1)
        return (y + param) % vocab
    if family == "next":                        # y_i = x_{i+1} + p (y_L = x_L + p)
        y = np.concatenate([x[:, 1:], x[:, -1:]], axis=1)
        return (y + param) % vocab
    raise ValueError(family)


FAMILIES: List[str] = [
    "copy", "shift", "negate", "mul", "affine", "xor",
    "bitrev", "parity_swap", "add_pos", "alt_shift", "prev", "next",
]


def make_tasks(
    vocab: int = 32, partitions: int = 10, input_len: int = 8, target_len: int = 8
) -> List[TaskSpec]:
    """The paper's 12 datasets x 10 partitions -> 120 tasks."""
    return [
        TaskSpec(f, p, vocab, input_len, target_len)
        for f in FAMILIES
        for p in range(partitions)
    ]


def sample_batch(spec: TaskSpec, rng: np.random.Generator, batch: int) -> Dict:
    """Returns {"tokens", "labels", "mask"} np arrays for the LPT loss."""
    off, vocab = _alphabet(spec)
    x = rng.integers(0, vocab, size=(batch, spec.input_len))
    y = _apply_family(spec.family, spec.param, x, vocab)[:, : spec.target_len]
    # layout: BOS x.. SEP y..  ; predict y tokens (shifted by one)
    inp = np.concatenate(
        [
            np.full((batch, 1), BOS),
            x + off,
            np.full((batch, 1), SEP),
            y + off,
        ],
        axis=1,
    ).astype(np.int32)
    tokens = inp[:, :-1]
    labels = inp[:, 1:].copy()
    mask = np.zeros_like(labels, dtype=np.float32)
    tgt_start = 1 + spec.input_len  # position of SEP in tokens; predicts y0
    mask[:, tgt_start:] = 1.0
    return {"tokens": tokens, "labels": labels, "mask": mask}


def batch_to_jnp(batch: Dict) -> Dict:
    return {k: jnp.asarray(v) for k, v in batch.items()}


def task_similarity(a: TaskSpec, b: TaskSpec) -> float:
    """Crude structural similarity (used only for trace construction /
    sanity checks — the Prompt Bank itself uses activation features)."""
    if a.family != b.family:
        return 0.0
    return 1.0 / (1.0 + abs(a.param - b.param))
