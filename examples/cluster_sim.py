"""Cluster-scale scenario: replay a spiky 20-minute LPT trace through
every registered scheduling policy; report SLO violations + cost.

    PYTHONPATH=src python examples/cluster_sim.py [--load medium] [--S 1.0]
    PYTHONPATH=src python examples/cluster_sim.py --tenants --shards 4
    PYTHONPATH=src python examples/cluster_sim.py --bursty --shards 8 \
        --elastic --cap-best-effort 10 --policies prompttuner
    PYTHONPATH=src python examples/cluster_sim.py --shards 2 --elastic \
        --bursty --trace-out run.trace.json --metrics-out run.jsonl

Policies come from the string-keyed registry — adding a new system is
one class in ``repro/cluster/policies/`` and it shows up here for free.
With ``--shards N`` each policy runs over an N-shard ClusterFabric
(``--placement`` picks the shard-placement strategy); ``--tenants``
switches to the 3-tenant premium/standard/best-effort mix and prints the
per-tenant breakdown.

``--trace-out`` / ``--metrics-out`` attach the telemetry plane to each
policy's run, print the SLO-attainment time-series report, and export a
Chrome-trace (open at https://ui.perfetto.dev) / structured JSONL for
the *last* policy listed (use ``--policies prompttuner`` to pick one).

``--alerts`` attaches the online :class:`repro.obs.AlertRules`
evaluator (SLO burn-rate, queue-pressure, quarantine-count) and prints
every fired/resolved alert; ``--forensics-out`` writes the
per-violation blame-attribution report (why each violated or shed job
missed its SLO) for the last policy.

``--chaos {crashes,preemptions,mixed}`` arms the fault plane with the
named hazard profile, seeded from ``--seed`` so the injected crash /
preemption / slowdown schedule is reproducible (and identical across
the policies being compared). ``--checkpoint SECONDS`` enables the
crash-recovery checkpoint model.
"""
import argparse
import sys
from dataclasses import replace

sys.path.insert(0, "src")

from repro.cluster import (
    BURSTY_TENANT_MIX,
    CHAOS_PROFILES,
    ClusterFabric,
    DEFAULT_TENANT_MIX,
    ElasticConfig,
    FaultPlane,
    SimConfig,
    TenantQuota,
    TraceConfig,
    clone_jobs,
    generate_tenant_mix,
    generate_trace,
    placements,
    policies,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--load", default="medium",
                    choices=["low", "medium", "high", "llama-30b",
                             "qwen7b-r1"])
    ap.add_argument("--S", type=float, default=1.0,
                    help="SLO emergence (smaller = more stringent)")
    ap.add_argument("--gpus", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", type=int, default=1,
                    help="fabric shard count (1 = monolithic engine)")
    ap.add_argument("--placement", default="llm-affinity",
                    choices=placements())
    ap.add_argument("--tenants", action="store_true",
                    help="3-tenant premium/standard/best-effort mix")
    ap.add_argument("--bursty", action="store_true",
                    help="spiky imbalanced tenant mix (implies --tenants)")
    ap.add_argument("--elastic", action="store_true",
                    help="attach the elastic control plane (work stealing "
                         "+ autoscaling; needs --shards >= 2 to act)")
    ap.add_argument("--cap-best-effort", type=float, default=None,
                    metavar="USD",
                    help="with --elastic: per-tenant cost cap on the "
                         "best-effort tenant (admission control)")
    ap.add_argument("--chaos", default=None, choices=sorted(CHAOS_PROFILES),
                    help="inject faults from the named hazard profile, "
                         "seeded by --seed (same schedule per policy)")
    ap.add_argument("--checkpoint", type=float, default=None, metavar="S",
                    help="with --chaos: checkpoint interval in sim seconds "
                         "(orphaned jobs resume from the last checkpoint)")
    ap.add_argument("--checkpoint-min", type=float, default=0.0, metavar="S",
                    help="with --checkpoint: jobs with less tuning compute "
                         "than this never snapshot (skips the write tax "
                         "where a resume credit can't plausibly pay off)")
    ap.add_argument("--policies", nargs="*", default=policies.available(),
                    help=f"subset of {policies.available()}")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record telemetry and write a Chrome-trace/"
                         "Perfetto JSON (e.g. run.trace.json)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="record telemetry and write the structured JSONL "
                         "export (timelines + metric windows + audit)")
    ap.add_argument("--alerts", action="store_true",
                    help="attach the online alert evaluator (burn-rate / "
                         "queue-pressure / quarantine rules); fired alerts "
                         "print per policy and land in the audit export")
    ap.add_argument("--forensics-out", default=None, metavar="PATH",
                    help="write the per-violation blame-attribution report "
                         "JSON for the last policy (implies telemetry)")
    args = ap.parse_args()
    observe = (args.trace_out is not None or args.metrics_out is not None
               or args.alerts or args.forensics_out is not None)

    elastic = None
    if args.elastic:
        quotas = ({"initech": TenantQuota(cost_usd=args.cap_best_effort)}
                  if args.cap_best_effort is not None else {})
        elastic = ElasticConfig(quotas=quotas)
    if args.tenants or args.bursty:
        # per-tenant loads come from the mix spec; --S still applies
        base_mix = BURSTY_TENANT_MIX if args.bursty else DEFAULT_TENANT_MIX
        mix = [replace(t, slo_emergence=args.S) for t in base_mix]
        jobs = generate_tenant_mix(mix, seed=args.seed)
        kind = "bursty " if args.bursty else ""
        desc = (f"{kind}3-tenant mix (per-tenant loads: "
                f"{', '.join(f'{t.name}={t.load}x{t.scale}' for t in mix)}"
                f", S={args.S}; --load ignored)")
    else:
        jobs = generate_trace(TraceConfig(load=args.load,
                                          slo_emergence=args.S,
                                          seed=args.seed))
        desc = f"load={args.load}, S={args.S}"
    chaos_desc = (f", chaos={args.chaos}" if args.chaos is not None else "")
    print(f"trace: {len(jobs)} LPT jobs over 20 min ({desc}, "
          f"fleet={args.gpus} GPUs, shards={args.shards}/"
          f"{args.placement}, seed={args.seed}{chaos_desc})\n")
    print(f"{'policy':14s} {'SLO viol %':>10s} {'cost $':>8s} "
          f"{'GPU-hours':>10s}")
    tel = None
    for name in args.policies:
        cfg = SimConfig(max_gpus=args.gpus,
                        checkpoint_interval_s=args.checkpoint,
                        checkpoint_min_compute_s=args.checkpoint_min)
        # fresh plane per policy: same seed => identical fault schedule
        faults = (FaultPlane(hazard=CHAOS_PROFILES[args.chaos],
                             seed=args.seed)
                  if args.chaos is not None else None)
        fab = ClusterFabric(cfg, name,
                            shards=args.shards, placement=args.placement,
                            elastic=elastic, faults=faults)
        if observe:
            from repro.obs import AlertRules, Telemetry
            alerts = AlertRules() if args.alerts else None
            tel = Telemetry(alerts=alerts).attach(fab)
        res = fab.run(clone_jobs(jobs))
        s = res.summary()
        extra = ""
        if fab.controller is not None:
            extra = (f"   steals={fab.controller.steals} "
                     f"resizes={fab.controller.resizes} "
                     f"rejected={len(fab.rejections)}")
        if faults is not None:
            extra += (f"   crashes={faults.crashes} "
                      f"preempts={faults.preemptions} "
                      f"retries={faults.retries} shed={faults.sheds}")
        print(f"{name:14s} {s['slo_violation_pct']:10.1f} "
              f"{s['cost_usd']:8.2f} {s['gpu_seconds'] / 3600:10.1f}{extra}")
        if (args.tenants or args.bursty) and name == "prompttuner":
            for tenant, row in res.summary_by_tenant().items():
                print(f"  · {tenant:12s} {row['slo_violation_pct']:10.1f} "
                      f"{row['cost_usd']:8.2f} "
                      f"{row['gpu_seconds'] / 3600:10.1f}")
        if tel is not None:
            print()
            print(tel.report(title=f"SLO attainment over time [{name}]"))
            if tel.alerts is not None and tel.alerts.history:
                print()
                print(f"alerts [{name}]:")
                for a in tel.alerts.history:
                    print(f"  t={a.time:7.1f}s {a.kind:14s} {a.detail}")
            print()
    if tel is not None:
        # exports carry the last policy's run
        if args.trace_out:
            print(f"chrome trace -> {tel.export_chrome_trace(args.trace_out)}"
                  "  (open at https://ui.perfetto.dev)")
        if args.metrics_out:
            print(f"jsonl export -> {tel.export_jsonl(args.metrics_out)}")
        if args.forensics_out:
            import json

            rep = tel.forensics()
            print()
            print(rep.render())
            with open(args.forensics_out, "w") as f:
                json.dump(rep.to_dict(), f, indent=2, default=float)
            print(f"forensics -> {args.forensics_out}")
    print("\n(prompttuner = warm/cold pools + Algorithms 1&2 + "
          "DelaySchedulable + Prompt Bank latency budget; per-tenant "
          "rows bill at the class price tier)")


if __name__ == "__main__":
    main()
