"""Cluster-scale scenario: replay a spiky 20-minute LPT trace through
PromptTuner, INFless and ElasticFlow; report SLO violations + cost.

    PYTHONPATH=src python examples/cluster_sim.py [--load medium] [--S 1.0]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.cluster import (
    SimConfig,
    TraceConfig,
    clone_jobs,
    generate_trace,
    make_system,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--load", default="medium",
                    choices=["low", "medium", "high", "llama-30b",
                             "qwen7b-r1"])
    ap.add_argument("--S", type=float, default=1.0,
                    help="SLO emergence (smaller = more stringent)")
    ap.add_argument("--gpus", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    jobs = generate_trace(TraceConfig(load=args.load, slo_emergence=args.S,
                                      seed=args.seed))
    print(f"trace: {len(jobs)} LPT jobs over 20 min "
          f"(load={args.load}, S={args.S}, fleet={args.gpus} GPUs)\n")
    print(f"{'system':14s} {'SLO viol %':>10s} {'cost $':>8s} "
          f"{'GPU-hours':>10s}")
    for name in ("prompttuner", "infless", "elasticflow"):
        res = make_system(name, SimConfig(max_gpus=args.gpus)).run(
            clone_jobs(jobs))
        s = res.summary()
        print(f"{name:14s} {s['slo_violation_pct']:10.1f} "
              f"{s['cost_usd']:8.2f} {s['gpu_seconds'] / 3600:10.1f}")
    print("\n(PromptTuner = warm/cold pools + Algorithms 1&2 + "
          "DelaySchedulable + Prompt Bank latency budget)")


if __name__ == "__main__":
    main()
