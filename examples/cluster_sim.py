"""Cluster-scale scenario: replay a spiky 20-minute LPT trace through
every registered scheduling policy; report SLO violations + cost.

    PYTHONPATH=src python examples/cluster_sim.py [--load medium] [--S 1.0]

Policies come from the string-keyed registry — adding a new system is
one class in ``repro/cluster/policies/`` and it shows up here for free.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.cluster import (
    SimConfig,
    TraceConfig,
    clone_jobs,
    generate_trace,
    policies,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--load", default="medium",
                    choices=["low", "medium", "high", "llama-30b",
                             "qwen7b-r1"])
    ap.add_argument("--S", type=float, default=1.0,
                    help="SLO emergence (smaller = more stringent)")
    ap.add_argument("--gpus", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policies", nargs="*", default=policies.available(),
                    help=f"subset of {policies.available()}")
    args = ap.parse_args()

    jobs = generate_trace(TraceConfig(load=args.load, slo_emergence=args.S,
                                      seed=args.seed))
    print(f"trace: {len(jobs)} LPT jobs over 20 min "
          f"(load={args.load}, S={args.S}, fleet={args.gpus} GPUs)\n")
    print(f"{'policy':14s} {'SLO viol %':>10s} {'cost $':>8s} "
          f"{'GPU-hours':>10s}")
    for name in args.policies:
        res = policies.build(name, SimConfig(max_gpus=args.gpus)).run(
            clone_jobs(jobs))
        s = res.summary()
        print(f"{name:14s} {s['slo_violation_pct']:10.1f} "
              f"{s['cost_usd']:8.2f} {s['gpu_seconds'] / 3600:10.1f}")
    print("\n(prompttuner = warm/cold pools + Algorithms 1&2 + "
          "DelaySchedulable + Prompt Bank latency budget)")


if __name__ == "__main__":
    main()
