"""Dry-run demo: lower + compile one (arch x shape) on the production
16x16 mesh and print the roofline terms.

    PYTHONPATH=src python examples/dryrun_demo.py [--arch qwen2-7b]
                                                  [--shape decode_32k]

NOTE: must run as its own process — it forces 512 host-platform devices.
"""
import argparse
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    from repro.launch.dryrun import dryrun_one

    rec = dryrun_one(args.arch, args.shape, multi_pod=args.multi_pod)
    t = rec["roofline"]
    print(f"\narch {args.arch} x {args.shape} on {rec['mesh']} "
          f"({rec['n_devices']} chips):")
    print(f"  compute term    {t['compute_s']:.3f} s")
    print(f"  memory term     {t['memory_s']:.3f} s")
    print(f"  collective term {t['collective_s']:.3f} s")
    print(f"  bottleneck      {t['dominant']}")
    print(f"  useful-FLOPs ratio (6ND / HLO) {rec['useful_flops_ratio']:.2f}")
    m = rec["memory"]
    print(f"  HBM/device: args {m['argument_size_in_bytes'] / 1e9:.2f} GB, "
          f"temps {m['temp_size_in_bytes'] / 1e9:.2f} GB")


if __name__ == "__main__":
    main()
