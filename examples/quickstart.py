"""Quickstart: LPT requests through the PromptTunerService front door.

    PYTHONPATH=src python examples/quickstart.py

The service ties the paper's pieces into one API (§4):

1. Load the pretrained testbed LLM (trains + caches on first run) and
   build the Prompt Bank (two-layer K-medoid over activation features).
2. Stand up ``PromptTunerService`` — bank + Eqn-1 scorer + scheduling
   policy behind a single ``submit`` / ``run_until_idle`` surface.
3. ``submit`` an LPT request: the §4.4.3 latency budget routes it
   through the bank, whose two-layer lookup picks the initial prompt.
4. Tune for real from the looked-up prompt vs. a manual (random) one;
   compare ITA — the paper's headline win.
5. Submit a follow-up request carrying the freshly tuned prompt: when
   its job finishes, the service inserts it into the bank (Fig 5b's
   online loop), so later similar requests start from it.
6. ``telemetry=True`` wires the fleet telemetry plane into the same
   front door: per-job lifecycle spans (``handle.timeline()``) and the
   SLO-attainment time-series report.
"""
import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.api import PromptTunerService, SubmitRequest
from repro.cluster import SimConfig
from repro.config import TuneConfig
from repro.core.bank_builder import (
    build_bank_from_pretrain,
    make_score_fn,
    select_manual,
)
from repro.core.prompt_bank import PromptBank
from repro.data import LoaderConfig, TaskLoader
from repro.train.pretrain import pretrain
from repro.tuning import PromptTuner, activation_features


def main():
    print("== 1. testbed LLM + Prompt Bank")
    pre = pretrain("gpt2-base", cache=True)
    t0 = time.time()
    bank = build_bank_from_pretrain(pre, variants_per_prompt=4)
    print(f"   {len(pre.tasks)} tasks, d_model={pre.model.cfg.d_model}; "
          f"bank: {len(bank)} candidates, {len(bank.medoid_ids)} clusters, "
          f"built in {time.time() - t0:.1f}s")

    task = pre.tasks[17]
    tune_cfg = TuneConfig(lr=0.5, batch_size=16, eval_every=5)
    # hold out the task's own optimized prompts: the bank must TRANSFER
    # prompts from similar tasks (the paper's premise)
    holdout = PromptBank(capacity=bank.capacity,
                         num_clusters=bank.num_clusters)
    holdout.add_candidates([e for e in bank.entries
                            if not e.origin.startswith(task.task_id + "/")])
    holdout.build()

    print("== 2. PromptTunerService front door")
    tasks_by_id = {t.task_id: t for t in pre.tasks}

    def score_factory(req):
        """Eqn-1 bound to the request's task eval set."""
        return make_score_fn(pre, tasks_by_id[req.task_id], tune_cfg)

    service = PromptTunerService(SimConfig(max_gpus=8), bank=holdout,
                                 score_fn_factory=score_factory,
                                 telemetry=True)

    print("== 3. submit: latency budget -> two-layer lookup (Eqn-1)")
    t0 = time.time()
    handle = service.submit(SubmitRequest(
        task_id=task.task_id, llm="gpt2-base", slo=60.0,
        iters_manual=400, iters_bank=120))
    print(f"   task={task.task_id}, SLO=60s, routed={handle.routed_through_bank}")
    print(f"   picked {handle.bank_origin} score={handle.bank_score:.3f} "
          f"({time.time() - t0:.1f}s; flat search would score "
          f"all {len(holdout)})")

    print("== 4. prompt tuning to target (bank init vs manual init)")
    loader = TaskLoader(task, LoaderConfig(batch_size=16))
    tuner = PromptTuner(pre.model, tune_cfg)
    own = tuner.score({"soft_prompt": jnp.asarray(
        pre.task_prompts[task.task_id])}, pre.params,
        loader.eval_batch(16))
    target = own * 1.5 + 0.05

    t0 = time.time()
    res_bank = tuner.tune(pre.params, loader,
                          {"soft_prompt": jnp.asarray(handle.initial_prompt)},
                          target_loss=target, max_iters=400)
    t_bank = time.time() - t0
    t0 = time.time()
    res_manual = tuner.tune(
        pre.params, loader,
        {"soft_prompt": jnp.asarray(select_manual(pre, seed=7))},
        target_loss=target, max_iters=400)
    t_manual = time.time() - t0
    print(f"   bank   init: ITA={res_bank['iters']:4d} "
          f"(reached={res_bank['reached']}, {t_bank:.0f}s)")
    print(f"   manual init: ITA={res_manual['iters']:4d} "
          f"(reached={res_manual['reached']}, {t_manual:.0f}s)")
    print(f"   ITA speedup from prompt reusing: "
          f"{res_manual['iters'] / max(res_bank['iters'], 1):.2f}x")

    print("== 5. online insertion (Fig 5b): tuned prompt -> bank")
    tuned = np.asarray(res_bank["prompt"]["soft_prompt"])
    feat = np.asarray(activation_features(
        pre.model, pre.params, jnp.asarray(tuned)))
    size0 = len(holdout)
    service.submit(SubmitRequest(
        task_id=task.task_id, llm="gpt2-base", slo=120.0,
        iters_manual=res_manual["iters"], iters_bank=res_bank["iters"],
        prompt=tuned, feature=feat))
    results = service.run_until_idle()
    done = [r for r in results if r.inserted_to_bank]
    print(f"   {len(results)} jobs scheduled+finished "
          f"(SLO violations: {sum(r.violated for r in results)}); "
          f"bank {size0} -> {len(holdout)} entries "
          f"({len(done)} fresh prompt inserted online)")
    print(f"   service summary: {service.summary()}")

    print("== 6. telemetry: per-job spans + SLO-attainment report")
    tl = handle.timeline()
    phases = ", ".join(f"{s.phase}={s.duration:.1f}s" for s in tl.spans
                       if s.end is not None)
    print(f"   job {tl.job_id} on shard {tl.shard}: {phases}")
    print(service.report(title="SLO attainment over time (quickstart)"))


if __name__ == "__main__":
    main()
