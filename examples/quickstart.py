"""Quickstart: one LPT request through the full PromptTuner pipeline.

    PYTHONPATH=src python examples/quickstart.py

1. Load the pretrained testbed LLM (trains + caches on first run).
2. Build the Prompt Bank (two-layer K-medoid over activation features).
3. A user submits an LPT job: task dataset + SLO.
4. The Workload Scheduler's latency budget routes it through the bank.
5. The bank's lookup picks the initial prompt (Eqn-1 score).
6. Prompt tuning runs to the accuracy target; compare ITA vs a manual
   (random) initial prompt.
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TuneConfig
from repro.core.bank_builder import (
    build_bank_from_pretrain,
    make_score_fn,
    select_manual,
)
from repro.data import LoaderConfig, TaskLoader
from repro.train.pretrain import pretrain
from repro.tuning import PromptTuner


def main():
    print("== 1. pretrained testbed LLM (gpt2-base analog)")
    pre = pretrain("gpt2-base", cache=True)
    print(f"   {len(pre.tasks)} tasks, d_model={pre.model.cfg.d_model}")

    print("== 2. Prompt Bank")
    t0 = time.time()
    bank = build_bank_from_pretrain(pre, variants_per_prompt=4)
    print(f"   {len(bank)} candidates, {len(bank.medoid_ids)} clusters, "
          f"built in {time.time() - t0:.1f}s")

    print("== 3. user submits an LPT job")
    task = pre.tasks[17]
    tune_cfg = TuneConfig(lr=0.5, batch_size=16, eval_every=5)
    print(f"   task={task.task_id}, SLO=60s")

    print("== 4-5. bank lookup (two-layer, Eqn-1 score)")
    # hold out the task's own optimized prompts: the bank must TRANSFER
    # prompts from similar tasks (the paper's premise)
    from repro.core.prompt_bank import PromptBank
    holdout = PromptBank(capacity=bank.capacity,
                         num_clusters=bank.num_clusters)
    holdout.add_candidates([e for e in bank.entries
                            if not e.origin.startswith(task.task_id + "/")])
    holdout.build()
    sc = make_score_fn(pre, task, tune_cfg)
    t0 = time.time()
    pick = holdout.lookup(sc)
    print(f"   picked {pick.entry.origin} score={pick.score:.3f} "
          f"({pick.evaluations} evals, {time.time() - t0:.1f}s; "
          f"flat search would need {len(bank)})")

    print("== 6. prompt tuning to target")
    loader = TaskLoader(task, LoaderConfig(batch_size=16))
    tuner = PromptTuner(pre.model, tune_cfg)
    own = tuner.score({"soft_prompt": jnp.asarray(
        pre.task_prompts[task.task_id])}, pre.params,
        loader.eval_batch(16))
    target = own * 1.5 + 0.05

    t0 = time.time()
    res_bank = tuner.tune(pre.params, loader,
                          {"soft_prompt": jnp.asarray(pick.entry.prompt)},
                          target_loss=target, max_iters=400)
    t_bank = time.time() - t0
    t0 = time.time()
    res_manual = tuner.tune(
        pre.params, loader,
        {"soft_prompt": jnp.asarray(select_manual(pre, seed=7))},
        target_loss=target, max_iters=400)
    t_manual = time.time() - t0
    print(f"   bank   init: ITA={res_bank['iters']:4d} "
          f"(reached={res_bank['reached']}, {t_bank:.0f}s)")
    print(f"   manual init: ITA={res_manual['iters']:4d} "
          f"(reached={res_manual['reached']}, {t_manual:.0f}s)")
    print(f"   ITA speedup from prompt reusing: "
          f"{res_manual['iters'] / max(res_bank['iters'], 1):.2f}x")


if __name__ == "__main__":
    main()
