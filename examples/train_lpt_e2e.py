"""End-to-end driver: prompt-tune a ~100M-parameter qwen2-family model for
a few hundred steps on CPU, with checkpointing — the full training path a
production job runs (model def -> data -> LPT step -> eval -> ckpt).

    PYTHONPATH=src python examples/train_lpt_e2e.py [--steps 300]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TuneConfig
from repro.configs import get_config
from repro.data import LoaderConfig, TaskLoader, TaskSpec, batch_to_jnp
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.train.checkpoint import save_checkpoint


def hundred_m_config():
    """qwen2-family scaled to ~100M params (assigned arch reduced in
    width/depth, same structure: GQA + QKV bias + SwiGLU)."""
    return get_config("qwen2-7b").with_overrides(
        num_layers=12, d_model=640, num_heads=10, num_kv_heads=2,
        head_dim=64, d_ff=2560, vocab_size=16384, max_seq_len=512,
        dtype="float32", param_dtype="float32", remat=False,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="artifacts/e2e_prompt.npz")
    args = ap.parse_args()

    cfg = hundred_m_config()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree.leaves(params))
    print(f"model: qwen2-family {n_params / 1e6:.0f}M params, "
          f"{cfg.num_layers}L d{cfg.d_model}")

    task = TaskSpec("shift", 3, vocab=256, input_len=12, target_len=12)
    loader = TaskLoader(task, LoaderConfig(batch_size=args.batch))
    tune_cfg = TuneConfig(prompt_len=16, lr=0.3, batch_size=args.batch)
    step, opt = make_train_step(model, tune_cfg)
    step = jax.jit(step)

    key = jax.random.key(1)
    prompt = {"soft_prompt": jax.random.normal(
        key, (tune_cfg.prompt_len, cfg.d_model)) * 0.02}
    opt_state = opt.init(prompt)

    eval_b = batch_to_jnp(loader.eval_batch(16))
    t0 = time.time()
    for it in range(1, args.steps + 1):
        batch = batch_to_jnp(next(loader))
        prompt, opt_state, loss = step(params, prompt, opt_state, batch)
        if it % 25 == 0 or it == 1:
            rate = it / (time.time() - t0)
            print(f"step {it:4d}  loss {float(loss):.4f}  "
                  f"({rate:.2f} steps/s)")
    save_checkpoint(args.ckpt, prompt, step=args.steps,
                    meta={"task": task.task_id, "arch": "qwen2-100m"})
    print(f"prompt checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
